"""Observability overhead benchmark — the honesty check for repro.obs.

Tracing is only trustworthy if it is cheap enough to leave on when you
need it and *free* when you don't.  Three measurements:

1. **Disabled overhead** on the bench_serve workload: A/B the paged
   serving engine with the tracer module present-but-off vs ... also off —
   the disabled path IS the default, so the honest statement of disabled
   cost is the measured per-call price of a no-op recording entry point
   times the event rate the enabled run would have produced.  Both the
   direct ns/call figure and the derived fraction of the workload are
   recorded (acceptance: ≤ 2%).

2. **Enabled overhead**: the same serving workload, best-of-N tokens/s
   with tracing off vs on (per-thread ring buffers recording scheduler
   tasks, prefill/decode spans, request lifetimes).  Acceptance: ≤ 10%.

3. **Fleet demo**: a 3-locality run traced end to end and merged into
   ``results/obs_trace_demo.json`` (a Perfetto-loadable Chrome trace);
   the flow-link audit (every cross-locality parcel arrow complete)
   is recorded alongside.

Writes ``results/BENCH_obs.json``.
"""
import json
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_obs.json"
DEMO = REPO / "results" / "obs_trace_demo.json"

ARCH = "starcoder2_3b"
MAX_BATCH = 8
CACHE_LEN = 128
MAX_NEW = 12
REQUESTS = 12
REPEATS = 3


def _workload(vocab: int, n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 61, size=n)
    return [rng.integers(1, vocab, size=int(L)).tolist() for L in lens]


def _serve_pass(model, params, vocab, name: str):
    """One serving pass; returns (tokens_per_s, recorded_event_count)."""
    from repro.obs import trace
    from repro.serve.engine import Engine, ServeConfig

    eng = Engine(model, params,
                 ServeConfig(max_batch=MAX_BATCH, cache_len=CACHE_LEN,
                             max_new_tokens=MAX_NEW, page_size=16,
                             paged=True, pipeline_admission=True, name=name))
    prompts = _workload(vocab, REQUESTS)
    eng.submit(prompts[0]).get(timeout=600)  # warmup: compile prefill/decode
    ev0 = sum(b["idx"] for b in _buffer_cursors())
    t0 = time.perf_counter()
    futs = [eng.submit(p) for p in prompts]
    total = sum(len(f.get(timeout=600)) for f in futs)
    wall = time.perf_counter() - t0
    ev1 = sum(b["idx"] for b in _buffer_cursors())
    del trace  # only used for the cursor probe below
    return total / wall, ev1 - ev0, wall


def _buffer_cursors():
    from repro.obs import trace

    with trace._lock:
        return [{"idx": b.idx} for b in trace._buffers]


def _noop_cost_ns(iters: int = 200_000) -> float:
    """Measured ns/call of the disabled recording entry points (the exact
    code instrumentation sites run when tracing is off)."""
    from repro.obs import trace

    assert not trace._enabled
    span, instant = trace.span, trace.instant
    t0 = time.perf_counter()
    for _ in range(iters):
        with span("x", "t"):
            pass
        instant("y", "t")
    dt = time.perf_counter() - t0
    return dt / (2 * iters) * 1e9


def _bench_overhead():
    import jax

    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.obs import trace

    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(jax.random.PRNGKey(0))

    trace.disable()
    noop_ns = _noop_cost_ns()

    # Interleave off/on passes, keep best-of-N of each: JIT caches and OS
    # noise hit both arms equally, the max is the honest steady state.
    off_tps, on_tps, on_events, on_wall = 0.0, 0.0, 0, 0.0
    for r in range(REPEATS):
        trace.disable()
        tps, _, _ = _serve_pass(model, params, cfg.vocab_size,
                                name=f"bench-obs-off#{r}")
        off_tps = max(off_tps, tps)
        trace.enable()
        tps, n_ev, wall = _serve_pass(model, params, cfg.vocab_size,
                                      name=f"bench-obs-on#{r}")
        if tps > on_tps:
            on_tps, on_events, on_wall = tps, n_ev, wall
        trace.disable()
        trace.clear()

    enabled_overhead = max(0.0, 1.0 - on_tps / off_tps) if off_tps else 0.0
    # disabled cost = no-op price × the event rate tracing would have seen
    event_rate = on_events / on_wall if on_wall else 0.0
    disabled_overhead = noop_ns * 1e-9 * event_rate
    return {
        "workload": {"arch": ARCH, "requests": REQUESTS,
                     "max_new": MAX_NEW, "max_batch": MAX_BATCH,
                     "repeats": REPEATS},
        "noop_call_ns": round(noop_ns, 2),
        "events_per_run": on_events,
        "event_rate_per_s": round(event_rate, 1),
        "tokens_per_s_disabled": round(off_tps, 2),
        "tokens_per_s_enabled": round(on_tps, 2),
        "tracing_disabled_overhead": round(disabled_overhead, 6),
        "tracing_enabled_overhead": round(enabled_overhead, 4),
        "disabled_within_2pct": disabled_overhead <= 0.02,
        "enabled_within_10pct": enabled_overhead <= 0.10,
    }


def _bench_fleet_demo():
    """3-locality traced serve run → one merged Perfetto-loadable JSON,
    then the ISSUE 9 analyzer over it: attribution coverage (how much of
    each request's wall time the critical path explains) is a recorded,
    regression-gated metric like the overhead numbers above."""
    from repro import net as rnet
    from repro.obs import attribution, export, trace
    from repro.serve.router import TIER_BATCH, TIER_INTERACTIVE, Router

    trace.clear()
    with rnet.running(3) as net:
        export.enable_fleet(net)
        try:
            from repro.serve.engine import ServeConfig

            router = Router.over_localities(
                net, ARCH,
                ServeConfig(max_batch=4, cache_len=CACHE_LEN,
                            max_new_tokens=8, page_size=16, paged=True,
                            pipeline_admission=True),
                smoke=True, plan="serve",
                tiers={"engine#1": TIER_INTERACTIVE, "engine#2": TIER_BATCH})
            prompts = _workload(1000, 6, seed=11)
            slos = [TIER_INTERACTIVE, TIER_BATCH, None] * 2
            outs = [router.submit(p, slo=s).get(timeout=600)
                    for p, s in zip(prompts, slos)]
            tr = export.export_chrome_trace(str(DEMO), net=net)
        finally:
            export.disable_fleet(net)
    trace.clear()

    links = export.flow_links(tr)
    complete = [v for v in links.values()
                if v["src"] is not None and v["dst"] is not None]
    cross = [v for v in complete if v["src"] != v["dst"]]
    pids = sorted({e["pid"] for e in tr["traceEvents"]})

    cps = attribution.analyze_requests(tr)
    report = attribution.slow_report(tr, cps)
    fracs = [cp.fraction for cp in cps.values()]
    return {
        "localities": 3,
        "requests": len(outs),
        "trace_path": str(DEMO.relative_to(REPO)),
        "trace_events": len(tr["traceEvents"]),
        "pids_in_trace": pids,
        "flow_links_complete": len(complete),
        "flow_links_cross_locality": len(cross),
        "all_localities_present": pids == [0, 1, 2],
        "requests_analyzed": len(cps),
        "attributed_fraction_min": round(min(fracs), 4) if fracs else 0.0,
        "attributed_fraction_mean": round(sum(fracs) / len(fracs), 4)
        if fracs else 0.0,
        "cross_locality_requests": sum(
            1 for cp in cps.values() if len(cp.localities()) >= 2),
        "clock_clamps": sum(cp.clamped_count for cp in cps.values()),
        "lossy": bool(tr.get("lossy", False)),
        "tiers": sorted(report["tiers"]),
        "attribution_95pct_met": bool(fracs) and min(fracs) >= 0.95,
    }


def _bench_export_tier():
    """ISSUE 10 smoke: a 2-locality fleet scraped over real HTTP through
    the strict OpenMetrics parser, a counter timeline persisted through
    the fleet sampler, and one fleet-top frame rendered from the scrape —
    the whole export tier exercised end to end, CI-gated."""
    from repro import net as rnet
    from repro.core import counters as C
    from repro.net.httpd import http_get
    from repro.obs import metrics as M
    from repro.obs import timeseries as TS
    from repro.obs import top as T
    from repro.obs.sampler import FleetSampler

    tl_path = REPO / "results" / "obs_timeline_demo.jsonl"
    # a histogram with real content so the scrape carries >= 1 native one
    h = C.default().histogram("/serve{engine#0}/request/latency")
    for v in (0.005, 0.01, 0.02, 0.04, 0.08):
        h.add(v)

    with rnet.running(2) as net:
        timeline = TS.TimelineWriter(str(tl_path), pattern="*",
                                     interval=0.05,
                                     meta={"source": "bench_obs"})
        sampler = FleetSampler(pattern="*", interval=0.05, net=net,
                               timeline=timeline)
        sampler.sample_once()
        with M.MetricsExporter(net=net) as ex:
            t0 = time.perf_counter()
            status, body = http_get(ex.url, timeout=120.0)
            scrape_s = time.perf_counter() - t0
        sampler.sample_once()
        timeline.close()

    parse_ok, fams, err = 0.0, {}, ""
    try:
        fams = M.parse_prometheus_text(body, strict=True)
        parse_ok = 1.0 if status == 200 else 0.0
    except ValueError as e:
        err = str(e)
    locs = {labels.get("locality")
            for info in fams.values() if info["type"] == "counter"
            for _n, labels, _v in info["samples"]}
    hist_fams = [f for f, i in fams.items() if i["type"] == "histogram"]

    summary = TS.summarize(str(tl_path))
    frame = T.render_frame(T.snapshot_from_metrics(body))
    return {
        "scrape_status": status,
        "scrape_s": round(scrape_s, 4),
        "scrape_bytes": len(body.encode("utf-8")),
        "scrape_strict_parse_ok": parse_ok,
        "scrape_parse_error": err,
        "scrape_families": len(fams),
        "scrape_histograms": len(hist_fams),
        "scrape_localities": len(locs - {None}),
        "timeline_path": str(tl_path.relative_to(REPO)),
        "timeline_records": summary["records"],
        "timeline_final_stride": summary["final_stride"],
        "timeline_has_utilization": bool(summary["utilization"]),
        "top_frame_lines": len(frame.splitlines()),
        "top_frame_ok": 1.0 if "fleet-top" in frame else 0.0,
    }


def run():
    res = {"overhead": _bench_overhead(), "fleet_demo": _bench_fleet_demo(),
           "export_tier": _bench_export_tier()}
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(res, indent=1))
    ov, demo, exp = res["overhead"], res["fleet_demo"], res["export_tier"]
    return [
        ("obs/noop_call_ns", ov["noop_call_ns"] * 1e-3,
         f"{ov['noop_call_ns']:.0f} ns/call disabled"),
        ("obs/disabled_overhead", 0.0,
         f"{ov['tracing_disabled_overhead'] * 100:.4f}% (<=2% "
         f"{'OK' if ov['disabled_within_2pct'] else 'FAIL'})"),
        ("obs/enabled_overhead", 0.0,
         f"{ov['tracing_enabled_overhead'] * 100:.2f}% (<=10% "
         f"{'OK' if ov['enabled_within_10pct'] else 'FAIL'})"),
        ("obs/fleet_demo_flow_links", 0.0,
         f"{demo['flow_links_cross_locality']} cross-locality arrows, "
         f"{demo['trace_events']} events"),
        ("obs/attribution", 0.0,
         f"{demo['attributed_fraction_min'] * 100:.1f}% min attributed "
         f"over {demo['requests_analyzed']} reqs (>=95% "
         f"{'OK' if demo['attribution_95pct_met'] else 'FAIL'})"),
        ("obs/export_scrape", exp["scrape_s"] * 1e6,
         f"{exp['scrape_families']} families, "
         f"{exp['scrape_histograms']} histograms, "
         f"{exp['scrape_localities']} localities, strict-parse "
         f"{'OK' if exp['scrape_strict_parse_ok'] else 'FAIL'}"),
        ("obs/export_timeline", 0.0,
         f"{exp['timeline_records']} records (stride "
         f"{exp['timeline_final_stride']}), utilization "
         f"{'OK' if exp['timeline_has_utilization'] else 'MISSING'}; "
         f"top frame {exp['top_frame_lines']} lines"),
    ]


def check() -> int:
    """``--check``: re-read the last run's JSON and enforce the ISSUE 10
    export-tier acceptance bars (CI calls this as ``make bench-obs-check``
    right after the bench job)."""
    try:
        res = json.loads(OUT.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"GATE FAILED — cannot read {OUT}: {e}")
        return 1
    ov = res.get("overhead", {})
    exp = res.get("export_tier", {})
    gates = [
        ("tracing disabled overhead <= 2%",
         ov.get("tracing_disabled_overhead", 1.0) <= 0.02),
        ("metrics scrape 200 + strict parse",
         exp.get("scrape_strict_parse_ok", 0.0) >= 1.0),
        (">= 1 native histogram in scrape",
         exp.get("scrape_histograms", 0) >= 1),
        ("counters from >= 2 localities",
         exp.get("scrape_localities", 0) >= 2),
        ("timeline persisted >= 2 records",
         exp.get("timeline_records", 0) >= 2),
        ("timeline yields utilization",
         bool(exp.get("timeline_has_utilization"))),
        ("fleet-top frame rendered",
         exp.get("top_frame_ok", 0.0) >= 1.0),
    ]
    bad = [name for name, ok in gates if not ok]
    for name, ok in gates:
        print(f"GATE {'ok  ' if ok else 'FAIL'} {name}")
    if bad:
        print(f"GATE FAILED — {len(bad)} export-tier gate(s): {bad}")
        return 1
    print("GATE PASS — export tier healthy")
    return 0


def main() -> None:
    import sys

    if "--check" in sys.argv:
        sys.exit(check())

    import repro.core as core

    core.init(pools={"default": 4, "prefill": 2, "io": 1})
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
    print(json.dumps(json.loads(OUT.read_text()), indent=1))
    core.finalize()


if __name__ == "__main__":
    main()
