"""Paper claim: OctoTiger at 96.8 % parallel efficiency on 643,280 cores.
Our analogue: parallel efficiency of the futurized train step when scaling
one pod (256 chips) → two pods (512 chips).  Both cells run the SAME
global batch (the assigned shape), so this is STRONG scaling:

    eff = T(256 chips) / (2 × T(512 chips))     (overlapped step model)

computed per arch for train_4k; the collective term picks up the DCI hop
and the halved per-chip work, everything else divides."""
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "dryrun"


def run():
    from repro.analysis.roofline import analyze, load_records

    rows = []
    pods = {r["arch"]: analyze(r) for r in load_records(OUT, "futurized", "pod")
            if r["shape"] == "train_4k"}
    multis = {r["arch"]: analyze(r) for r in load_records(OUT, "futurized", "multipod")
              if r["shape"] == "train_4k"}
    effs = []
    for arch in sorted(set(pods) & set(multis)):
        t1 = max(pods[arch].compute_s, pods[arch].memory_s, pods[arch].collective_s)
        t2 = max(multis[arch].compute_s, multis[arch].memory_s,
                 multis[arch].collective_s)
        eff = t1 / (2 * t2) if t2 else 0.0  # strong scaling: fixed global work
        effs.append(eff)
        rows.append((f"efficiency/{arch}", 0.0, f"{100 * eff:.1f}% @512 chips"))
    if effs:
        import statistics

        rows.append(("efficiency/mean_strong_scaling", 0.0,
                     f"{100 * statistics.mean(effs):.1f}% (paper: 96.8%)"))
    return rows
