"""Futures/promises semantics (HPX P1)."""
import threading
import time

import pytest

import repro.core as core
from repro.core.future import (Future, FutureError, Promise,
                               make_exceptional_future, make_ready_future,
                               unwrap, when_all, when_any)


def test_promise_future_basic(rt):
    p = Promise()
    f = p.future()
    assert not f.is_ready()
    p.set_value(42)
    assert f.is_ready() and f.has_value()
    assert f.get() == 42


def test_promise_single_shot(rt):
    p = Promise()
    p.set_value(1)
    with pytest.raises(FutureError):
        p.set_value(2)


def test_exception_propagates(rt):
    f = core.spawn(lambda: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        f.get()
    assert f.has_exception()


def test_then_chain(rt):
    f = core.spawn(lambda: 3)
    g = f.then_value(lambda x: x * 2).then_value(lambda x: x + 1)
    assert g.get() == 7


def test_then_sees_exception(rt):
    f = make_exceptional_future(ValueError("boom"))
    g = f.then(lambda fut: "caught" if fut.has_exception() else "missed")
    assert g.get() == "caught"


def test_when_all_and_any(rt):
    fs = [core.spawn(lambda i=i: i) for i in range(20)]
    ready = when_all(fs).get()
    assert sorted(f.get() for f in ready) == list(range(20))
    slow = core.spawn(lambda: (time.sleep(0.5), "slow")[1])
    fast = make_ready_future("fast")
    assert when_any([slow, fast]).get() == 1


def test_when_all_empty(rt):
    assert when_all([]).get() == []


def test_unwrap_nested(rt):
    v = unwrap({"a": make_ready_future(1),
                "b": [make_ready_future(2), 3],
                "c": make_ready_future(make_ready_future(4))})
    assert v == {"a": 1, "b": [2, 3], "c": 4}


def test_get_timeout(rt):
    p = Promise()
    with pytest.raises(TimeoutError):
        p.future().get(timeout=0.05)


def test_nested_blocking_does_not_deadlock(rt):
    """Blocked tasks help along (HPX thread suspension analogue)."""

    def fib(n):
        if n < 2:
            return n
        a = core.spawn(fib, n - 1)
        return a.get() + fib(n - 2)

    assert core.spawn(fib, 13).get(timeout=60) == 233


def test_set_exception_reaches_late_registered_callbacks(rt):
    """Callbacks registered AFTER a future failed must still observe the
    exception — the remote-completion path registers its bookkeeping hook
    whenever the result frame happens to land, including 'already'."""
    f = make_exceptional_future(ValueError("late"))
    seen = []
    f.on_ready(lambda fut: seen.append(fut.exception()))
    assert len(seen) == 1 and isinstance(seen[0], ValueError)
    # and a .then() continuation attached late sees it too
    g = f.then(lambda fut: type(fut.exception()).__name__)
    assert g.get(timeout=10) == "ValueError"
    # value-projecting continuation propagates the error instead
    with pytest.raises(ValueError, match="late"):
        f.then_value(lambda v: v).get(timeout=10)


def test_callbacks_fire_outside_the_lock(rt):
    """A callback may re-enter the same future (get / another on_ready /
    then) without deadlocking — i.e. completion and the already-ready path
    must never hold the future's lock while running callbacks."""
    order = []

    # case 1: callback registered after completion re-enters immediately
    f = make_ready_future(10)
    f.on_ready(lambda fut: (order.append(fut.get(timeout=1)),
                            fut.on_ready(lambda g: order.append(g.get(timeout=1) + 1))))
    assert order == [10, 11]

    # case 2: callback registered before completion re-enters from _set
    # (wait() inside the callback would deadlock if _set held the lock)
    p = Promise()
    fut = p.future()
    fut.on_ready(lambda g: order.append((g.wait(timeout=1),
                                         type(g.exception()).__name__)))
    p.set_exception(RuntimeError("x"))
    assert order == [10, 11, (True, "RuntimeError")]


def test_promise_set_from_relays_value_and_exception(rt):
    src_ok = make_ready_future(5)
    dst: Promise = Promise()
    dst.set_from(src_ok)
    assert dst.future().get(timeout=1) == 5

    src_bad = make_exceptional_future(KeyError("k"))
    dst2: Promise = Promise()
    dst2.set_from(src_bad)
    with pytest.raises(KeyError):
        dst2.future().get(timeout=1)
