"""Futures/promises semantics (HPX P1)."""
import threading
import time

import pytest

import repro.core as core
from repro.core.future import (Future, FutureError, Promise,
                               make_exceptional_future, make_ready_future,
                               unwrap, when_all, when_any)


def test_promise_future_basic(rt):
    p = Promise()
    f = p.future()
    assert not f.is_ready()
    p.set_value(42)
    assert f.is_ready() and f.has_value()
    assert f.get() == 42


def test_promise_single_shot(rt):
    p = Promise()
    p.set_value(1)
    with pytest.raises(FutureError):
        p.set_value(2)


def test_exception_propagates(rt):
    f = core.spawn(lambda: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        f.get()
    assert f.has_exception()


def test_then_chain(rt):
    f = core.spawn(lambda: 3)
    g = f.then_value(lambda x: x * 2).then_value(lambda x: x + 1)
    assert g.get() == 7


def test_then_sees_exception(rt):
    f = make_exceptional_future(ValueError("boom"))
    g = f.then(lambda fut: "caught" if fut.has_exception() else "missed")
    assert g.get() == "caught"


def test_when_all_and_any(rt):
    fs = [core.spawn(lambda i=i: i) for i in range(20)]
    ready = when_all(fs).get()
    assert sorted(f.get() for f in ready) == list(range(20))
    slow = core.spawn(lambda: (time.sleep(0.5), "slow")[1])
    fast = make_ready_future("fast")
    assert when_any([slow, fast]).get() == 1


def test_when_all_empty(rt):
    assert when_all([]).get() == []


def test_unwrap_nested(rt):
    v = unwrap({"a": make_ready_future(1),
                "b": [make_ready_future(2), 3],
                "c": make_ready_future(make_ready_future(4))})
    assert v == {"a": 1, "b": [2, 3], "c": 4}


def test_get_timeout(rt):
    p = Promise()
    with pytest.raises(TimeoutError):
        p.future().get(timeout=0.05)


def test_nested_blocking_does_not_deadlock(rt):
    """Blocked tasks help along (HPX thread suspension analogue)."""

    def fib(n):
        if n < 2:
            return n
        a = core.spawn(fib, n - 1)
        return a.get() + fib(n - 2)

    assert core.spawn(fib, 13).get(timeout=60) == 233
