"""OpenMetrics exposition: renderer ↔ strict-parser round trip, and a
live 2-locality fleet scrape over real HTTP (ISSUE 10 tentpole)."""

import math

import pytest

from repro.core import counters as C
from repro.obs import metrics as M


# ------------------------------------------------------------ name mapping
def test_counter_to_metric_mapping():
    cases = {
        "/scheduler{default}/idle-rate":
            ("repro_scheduler_idle_rate", {"pool": "default"}),
        "/scheduler{default}/steals/victim#0/thief#1":
            ("repro_scheduler_steals",
             {"pool": "default", "victim": "0", "thief": "1"}),
        "/serve{engine#2}/request/latency":
            ("repro_serve_request_latency", {"engine": "2"}),
        "/obs{blame/compute}/total":
            ("repro_obs_total", {"tier": "compute"}),
        "/net{locality#0/peer#1}/credit/inflight_bytes":
            ("repro_net_credit_inflight_bytes",
             {"locality": "0", "peer": "1"}),
        "/fleet{admission}/open":
            ("repro_fleet_open", {"instance": "admission"}),
    }
    for path, (name, labels) in cases.items():
        got_name, got_labels = M.counter_to_metric(path)
        assert got_name == name, path
        assert got_labels == labels, path
        assert M._NAME_OK_RE.match(got_name), got_name


# ------------------------------------------------------------- round trip
def _registry_with_everything():
    reg = C.CounterRegistry()
    reg.counter("/scheduler{default}/tasks/cumulative").increment(42)
    reg.gauge("/fleet{controller}/occupancy").set(0.75)
    reg.register_callable("/scheduler{default}/idle-rate", lambda: 0.125)
    reg.register_callable("/scheduler{default}/time/busy", lambda: 9.5,
                          kind="counter")
    h = reg.histogram("/serve{engine#0}/request/latency")
    for v in [0.001, 0.002, 0.5, 1.0, -1.0, 0.004] * 3:
        h.add(v)
    return reg


def test_render_parse_round_trip_strict():
    reg = _registry_with_everything()
    sweep = {0: reg.snapshot_export("*"),
             1: {"error": "ConnectionError('peer gone')"}}
    text = M.render_openmetrics(sweep)
    fams = M.parse_prometheus_text(text, strict=True)

    # counter-vs-gauge typing: cumulative counters got _total + counter
    assert fams["repro_scheduler_tasks_cumulative_total"]["type"] == "counter"
    assert fams["repro_scheduler_time_busy_total"]["type"] == "counter"
    assert fams["repro_scheduler_idle_rate"]["type"] == "gauge"
    assert fams["repro_fleet_occupancy"]["type"] == "gauge"

    # histogram: cumulative buckets, +Inf == _count, sum preserved
    hist = fams["repro_serve_request_latency"]
    assert hist["type"] == "histogram"
    buckets = [(labels["le"], v) for name, labels, v in hist["samples"]
               if name.endswith("_bucket")]
    assert buckets[-1][0] == "+Inf" and buckets[-1][1] == 18
    cums = [v for _le, v in buckets]
    assert cums == sorted(cums), "buckets must be cumulative-monotone"
    (sum_v,) = [v for name, _l, v in hist["samples"]
                if name.endswith("_sum")]
    assert sum_v == pytest.approx(1.521, abs=1e-9)

    # dead peer degraded to repro_up 0, live one reads 1
    ups = {labels["locality"]: v
           for _n, labels, v in fams["repro_up"]["samples"]}
    assert ups == {"0": 1.0, "1": 0.0}


def test_label_escaping_round_trip():
    raw = 'weird\\value"with\nnewline'
    line = f'm_x{{a="{M._escape_label(raw)}"}} 1\n'
    fams = M.parse_prometheus_text("# TYPE m_x gauge\n" + line, strict=True)
    (_n, labels, v) = fams["m_x"]["samples"][0]
    assert labels["a"] == raw and v == 1.0


def test_bucket_cap_merges_and_conserves_counts():
    reg = C.CounterRegistry()
    h = reg.histogram("/serve{engine#0}/step/duration")
    for i in range(200):  # hundreds of distinct log buckets
        h.add(1.0001 * (1.2 ** (i % 90)))
    text = M.render_openmetrics({0: reg.snapshot_export("*")})
    fams = M.parse_prometheus_text(text, strict=True)
    samples = fams["repro_serve_step_duration"]["samples"]
    buckets = [s for s in samples if s[0].endswith("_bucket")]
    assert len(buckets) <= M.BUCKET_CAP + 1  # merged buckets + the +Inf one
    assert buckets[-1][2] == 200  # nothing lost in the merge


@pytest.mark.parametrize("bad", [
    "no_type_declared 1\n",                                   # undeclared
    "# TYPE m counter\nm 1\n",                                # no _total
    "# TYPE m counter\nm_total -5\n",                         # negative
    "# TYPE m histogram\nm_bucket{le=\"1\"} 2\nm_count 2\n",  # no +Inf
    ("# TYPE m histogram\nm_bucket{le=\"1\"} 5\n"
     "m_bucket{le=\"+Inf\"} 2\nm_count 2\n"),                 # non-monotone
    ("# TYPE m histogram\nm_bucket{le=\"+Inf\"} 3\n"
     "m_count 7\n"),                                          # +Inf != count
])
def test_strict_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        M.parse_prometheus_text(bad, strict=True)


def test_error_kind_records_become_scrape_error_gauge():
    sweep = {0: {"/fleet{x}/boom": {"kind": "error", "error": "ZeroDiv"},
                 "/fleet{x}/ok": {"kind": "gauge", "value": 1.0}}}
    fams = M.parse_prometheus_text(M.render_openmetrics(sweep), strict=True)
    (_n, labels, v) = fams["repro_scrape_counter_errors"]["samples"][0]
    assert labels["locality"] == "0" and v == 1.0
    assert "repro_fleet_ok" in fams


# ------------------------------------------------- live endpoint, 1 locality
def test_http_endpoint_scrape_local(rt):
    from repro.net.httpd import http_get

    reg = _registry_with_everything()
    with M.MetricsExporter(registry=reg) as ex:
        status, body = http_get(ex.url)
        assert status == 200
        fams = M.parse_prometheus_text(body, strict=True)
        assert "repro_serve_request_latency" in fams
        status, _ = http_get(f"http://127.0.0.1:{ex.port}/nope")
        assert status == 404
        assert ex.scrapes >= 1


# ------------------------------------------------- live fleet, 2 localities
def test_fleet_scrape_two_localities(rt, net_factory):
    from repro.net.httpd import http_get

    net = net_factory(2)
    # give the exposition a histogram with real content on locality 0
    h = C.default().histogram("/serve{engine#0}/request/latency")
    for v in (0.01, 0.02, 0.04):
        h.add(v)
    with M.MetricsExporter(net=net) as ex:
        status, body = http_get(ex.url, timeout=120.0)
    assert status == 200
    fams = M.parse_prometheus_text(body, strict=True)

    # ≥1 native histogram made it through the strict parser
    hist_fams = [f for f, info in fams.items() if info["type"] == "histogram"]
    assert "repro_serve_request_latency" in hist_fams

    # counters arrived from BOTH localities (every locality registers its
    # scheduler's cumulative task counters at bootstrap)
    locs = {labels.get("locality")
            for info in fams.values() if info["type"] == "counter"
            for _n, labels, _v in info["samples"]}
    assert {"0", "1"} <= locs

    # both peers were reachable
    ups = {labels["locality"]: v
           for _n, labels, v in fams["repro_up"]["samples"]}
    assert ups.get("0") == 1.0 and ups.get("1") == 1.0

    # the scheduler's idle-rate gauges are part of the exposition
    assert "repro_scheduler_idle_rate" in fams
    for _n, labels, v in fams["repro_scheduler_idle_rate"]["samples"]:
        assert 0.0 <= v <= 1.0
        assert "pool" in labels
