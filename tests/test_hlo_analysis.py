"""Static HLO profiler: collectives, trip counts, dot flops (on synthetic
HLO text — the dry-run exercises the real thing)."""
import textwrap

from repro.dist.hlo_analysis import HloModule, parse_collectives

HLO = textwrap.dedent("""
    HloModule test

    %cond (arg: (s32[], f32[8,8])) -> pred[] {
      %arg = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %c = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %arg = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %x = f32[8,8] get-tuple-element(%arg), index=1
      %ar = f32[8,8] all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), use_global_device_ids=true, to_apply=%sum
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ar)
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (p0: f32[8,8], p1: f32[8,16]) -> f32[8,8] {
      %p0 = f32[8,8] parameter(0)
      %p1 = f32[8,16] parameter(1)
      %ag = f32[8,128] all-gather(%p1), channel_id=2, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}, use_global_device_ids=true
      %d = f32[8,8] dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %init = s32[] constant(0)
      %tup = (s32[], f32[8,8]) tuple(%init, %d)
      %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
      ROOT %out = f32[8,8] get-tuple-element(%w), index=1
    }
""")


def test_trip_count_multiplies_loop_collectives():
    mod = HloModule(HLO, 256)
    coll = mod.collectives()
    kinds = {o.kind: o for o in coll.ops}
    ar = kinds["all-reduce"]
    assert ar.trip_count == 12
    ag = kinds["all-gather"]
    assert ag.trip_count == 1
    assert ag.group_size == 8


def test_all_gather_operand_inferred_from_result():
    mod = HloModule(HLO, 256)
    ag = [o for o in mod.collectives().ops if o.kind == "all-gather"][0]
    # result 8x128 f32 = 4096B over gs=8 → operand 512B
    assert ag.operand_bytes == 8 * 128 * 4 // 8


def test_dot_flops_counts_entry_once():
    mod = HloModule(HLO, 256)
    # dot 8x8x8: 2*8*8*8 = 1024 flops, entry multiplier 1
    assert mod.dot_flops() == 2 * 8 * 8 * 8


def test_wire_byte_model():
    mod = HloModule(HLO, 256)
    ar = [o for o in mod.collectives().ops if o.kind == "all-reduce"][0]
    operand = 8 * 8 * 4
    assert ar.wire_bytes_per_device == 2 * operand * 15 // 16


def test_cross_pod_classification():
    hlo = HLO.replace("replica_groups=[16,16]<=[16,16]T(1,0)",
                      "replica_groups=[256,2]<=[2,256]T(1,0)")
    mod = HloModule(hlo, 512)
    ar = [o for o in mod.collectives().ops if o.kind == "all-reduce"][0]
    assert ar.crosses_pod
    assert ar.group_size == 2
    # and the original data-axis groups on 512 devices stay within a pod:
    mod2 = HloModule(HLO.replace("<=[16,16]", "<=[32,16]").replace("[16,16]<=", "[32,16]<="), 512)
    ar2 = [o for o in mod2.collectives().ops if o.kind == "all-reduce"][0]
    assert not ar2.crosses_pod


def test_memory_traffic_positive():
    assert HloModule(HLO, 256).memory_traffic() > 0
