"""APEX-style performance counters (HPX P5, paper §2.4)."""
import time

from repro.core.counters import Counter, CounterRegistry, Gauge, TimerCounter


def test_counter_monotonic():
    c = Counter("/x")
    c.increment()
    c.increment(2.5)
    assert c.get_value() == 3.5
    c.reset()
    assert c.get_value() == 0.0


def test_gauge_set():
    g = Gauge("/g")
    g.set(7.0)
    assert g.get_value() == 7.0


def test_timer_stats():
    t = TimerCounter("/t")
    with t.time():
        time.sleep(0.01)
    t.add(0.05)
    s = t.stats()
    assert s["count"] == 2
    assert s["max"] >= 0.05
    assert s["mean"] > 0
    assert t.ema is not None


def test_registry_query_glob():
    reg = CounterRegistry()
    reg.counter("/scheduler{p#0}/tasks/executed").increment(3)
    reg.counter("/scheduler{p#0}/tasks/stolen").increment(1)
    reg.gauge("/agas{l#0}/objects/count").set(5)
    got = dict(reg.query("/scheduler*"))
    assert got == {"/scheduler{p#0}/tasks/executed": 3.0,
                   "/scheduler{p#0}/tasks/stolen": 1.0}
    assert reg.get_value("/agas{l#0}/objects/count") == 5.0


def test_registry_callable_counter():
    reg = CounterRegistry()
    state = {"n": 0}
    reg.register_callable("/lazy/value", lambda: state["n"])
    state["n"] = 9
    assert reg.get_value("/lazy/value") == 9.0


def test_snapshot_consistent_while_counters_register():
    """query/snapshot must copy the (name, counter) pairs under the lock
    and evaluate OUTSIDE it: a callable counter that registers another
    counter mid-read (what a parcelport pump thread does on first use of a
    connection) used to die with "dict changed size during iteration"."""
    reg = CounterRegistry()
    reg.counter("/net{l#0}/parcels/sent").increment(2)

    def probe():
        # a lazily-created counter appearing during the sweep
        reg.counter(f"/net{{l#0}}/late/{reg.get_value('/net{l#0}/parcels/sent'):.0f}")
        return 1.0

    reg.register_callable("/net{l#0}/probe", probe)
    snap = reg.snapshot()  # must not raise
    assert snap["/net{l#0}/parcels/sent"] == 2.0
    assert snap["/net{l#0}/probe"] == 1.0
    got = dict(reg.query("/net*"))
    assert got["/net{l#0}/parcels/sent"] == 2.0


def test_snapshot_concurrent_registration_threads():
    """Hammer query() while another thread registers: every returned pair
    must be internally consistent (value belongs to the named counter)."""
    import threading

    reg = CounterRegistry()
    for i in range(8):
        reg.counter(f"/seed/{i}").increment(i)
    stop = threading.Event()

    def churn():
        k = 0
        while not stop.is_set():
            # bounded namespace: membership still flips under the sweep
            # (get-or-create), registry size stays O(1)
            reg.counter(f"/churn/{k % 64}").increment(1)
            k += 1

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        for _ in range(200):
            for name, value in reg.query("/seed/*"):
                assert value == float(name.rsplit("/", 1)[1])
            reg.snapshot()
    finally:
        stop.set()
        t.join(timeout=5)


def test_counters_visible_through_agas(rt):
    """Paper: counters are readable via AGAS under their symbolic name."""
    from repro.core import agas, counters

    c = counters.default().counter("/visible/via/agas")
    counters.default().register(c)
    c.increment(4)
    resolved = agas.default().resolve("/counters/visible/via/agas")
    assert resolved.get_value() == 4.0
