"""Sharding plan resolution: divisibility guard, FCFS mesh-axis use."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.plan import bsp_plan, futurized_plan, get_plan, optimized_plan


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_tp_axes_resolve():
    plan = futurized_plan()
    m = _mesh11()
    assert plan.spec(("embed", "mlp"), (64, 128), m) == P("data", "model")
    assert plan.spec(("vocab", "embed"), (128, 64), m) == P("model", "data")


def test_divisibility_guard_replicates():
    plan = futurized_plan()
    m = _mesh11()
    # 1-device axes always divide; simulate with a fake shape check on the
    # spec logic via a non-divisible dim against a >1 axis using mesh shape
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    assert plan.spec(("kv_heads",), (7,), mesh) == P(*[ "model"]) or True
    # real check happens in dry-run meshes; here assert the code path runs
    assert plan.spec(("heads",), (6,), mesh) in (P("model"), P(None), P())


def test_fcfs_axis_allocation():
    """experts and mlp both map to model: first dim wins, second replicates."""
    plan = futurized_plan()
    m = _mesh11()
    spec = plan.spec(("experts", "embed", "mlp"), (64, 32, 128), m)
    assert spec == P("model", "data")  # mlp dropped (trailing None trimmed)


def test_bsp_has_no_fsdp():
    plan = bsp_plan()
    m = _mesh11()
    assert plan.spec(("embed", "mlp"), (64, 128), m) == P(None, "model")
    assert plan.gather_upfront and plan.remat_policy == "full"


def test_optimized_plan_shards_kv_seq():
    plan = optimized_plan()
    m = _mesh11()
    assert plan.spec(("batch", "kv_seq"), (8, 128), m) == P("data", "model")
    assert plan.bf16_boundaries  # pod compression off by default (XLA CPU crash; see EXPERIMENTS)


def test_plan_registry():
    for name in ("bsp", "futurized", "optimized"):
        assert get_plan(name).name == name
    with pytest.raises(KeyError):
        get_plan("nope")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["embed", "mlp", "heads", "vocab", "experts",
                                 "layers", None]), min_size=1, max_size=4))
def test_spec_never_duplicates_mesh_axes(axes):
    plan = futurized_plan()
    m = _mesh11()
    shape = tuple(16 for _ in axes)
    spec = plan.spec(tuple(axes), shape, m)
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat)), f"duplicate axis in {spec}"
