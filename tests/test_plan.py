"""Sharding plan resolution: divisibility guard, FCFS mesh-axis use."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.plan import bsp_plan, futurized_plan, get_plan, optimized_plan


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _abstract_mesh(shape):
    """AbstractMesh for >1 axis sizes without real devices (ctor signature
    differs across jax versions)."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape.items()))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape.values()),
                                         tuple(shape.keys()))


def test_tp_axes_resolve():
    plan = futurized_plan()
    m = _mesh11()
    assert plan.spec(("embed", "mlp"), (64, 128), m) == P("data", "model")
    assert plan.spec(("vocab", "embed"), (128, 64), m) == P("model", "data")


def test_divisibility_guard_replicates():
    plan = futurized_plan()
    # a >1 model axis without real devices: 7 kv-heads on a 4-way axis must
    # REPLICATE (never emit a non-dividing shard), 8 must shard
    big = _abstract_mesh({"model": 4})
    assert plan.spec(("kv_heads",), (7,), big) == P()
    assert plan.spec(("kv_heads",), (8,), big) == P("model")
    # joint multi-axis degree is guarded too: batch → (pod, data) = 8-way
    pods = _abstract_mesh({"pod": 2, "data": 4})
    assert plan.spec(("batch",), (12,), pods) == P("pod")  # 8∤12, 2|12
    # 1-device axes always divide (the code path still runs)
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    assert plan.spec(("heads",), (6,), mesh) in (P("model"), P(None), P())


def test_fcfs_axis_allocation():
    """experts and mlp both map to model: first dim wins, second replicates."""
    plan = futurized_plan()
    m = _mesh11()
    spec = plan.spec(("experts", "embed", "mlp"), (64, 32, 128), m)
    assert spec == P("model", "data")  # mlp dropped (trailing None trimmed)


def test_bsp_has_no_fsdp():
    plan = bsp_plan()
    m = _mesh11()
    assert plan.spec(("embed", "mlp"), (64, 128), m) == P(None, "model")
    assert plan.gather_upfront and plan.remat_policy == "full"


def test_optimized_plan_shards_kv_seq():
    plan = optimized_plan()
    m = _mesh11()
    assert plan.spec(("batch", "kv_seq"), (8, 128), m) == P("data", "model")
    assert plan.bf16_boundaries  # pod compression off by default (XLA CPU crash; see EXPERIMENTS)


def test_plan_registry():
    for name in ("bsp", "futurized", "optimized"):
        assert get_plan(name).name == name
    with pytest.raises(KeyError):
        get_plan("nope")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["embed", "mlp", "heads", "vocab", "experts",
                                 "layers", None]), min_size=1, max_size=4))
def test_spec_never_duplicates_mesh_axes(axes):
    plan = futurized_plan()
    m = _mesh11()
    shape = tuple(16 for _ in axes)
    spec = plan.spec(tuple(axes), shape, m)
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat)), f"duplicate axis in {spec}"


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["batch", "embed", "mlp", "heads", "kv_heads",
                                 "vocab", "experts", "kv_seq", "layers", None]),
                min_size=1, max_size=4),
       st.data())
def test_spec_sharded_dims_always_divisible(axes, data):
    """Property: for every plan, mesh shape, and tensor shape, a sharded dim
    is always divisible by the joint degree of its assigned mesh axes."""
    plan = get_plan(data.draw(st.sampled_from(["bsp", "futurized",
                                               "optimized", "serve"])))
    mesh = _abstract_mesh({
        "pod": data.draw(st.sampled_from([1, 2])),
        "data": data.draw(st.sampled_from([1, 2, 3, 4])),
        "model": data.draw(st.sampled_from([1, 2, 4, 8])),
    })
    sizes = dict(mesh.shape)
    shape = tuple(data.draw(st.integers(1, 64)) for _ in axes)
    spec = plan.spec(tuple(axes), shape, mesh)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            continue
        parts = entry if isinstance(entry, tuple) else (entry,)
        degree = 1
        for p in parts:
            degree *= sizes[p]
        assert dim % degree == 0, (plan.name, axes, shape, spec)


def test_registry_round_trip_all_plans():
    """get_plan(plan.name) reproduces the plan, and keyword overrides ride
    through dataclasses.replace without disturbing the registry entry."""
    for name in ("bsp", "futurized", "optimized", "serve"):
        p = get_plan(name)
        q = get_plan(p.name)
        assert q == p and q is not p
        r = get_plan(name, microbatches=4)
        assert r.microbatches == 4 and r.name == name
        assert get_plan(name).microbatches == 1  # registry not mutated
