"""Multi-locality runtime: real processes, parcels over the wire,
distributed AGAS with generation-based cache invalidation.

One 3-locality net per module (spawned processes are ~seconds each);
3 localities so worker↔worker traffic exercises the root's frame switch.
Helper actions live at module level: worker processes resolve them by
dotted name (``test_net_localities.<fn>``) and import this module lazily.
"""

import numpy as np
import pytest

import repro.core as core
from repro import net as rnet
from repro.core import agas, parcel
from repro.core.agas import GID
from repro.net.locality import _gid_key


# ----------------------------------------------------------- helper actions
@parcel.action
def tree_scale_sum(obj, s):
    """Object-targeted: runs where the data lives."""
    return float(sum(float(np.sum(v)) for v in obj.values()) * s)


@parcel.action
def raise_value_error(obj, msg):
    raise ValueError(msg)


def echo_locality(rt, payload):
    """Plain (undecorated) module function: exercises qualname fallback."""
    return rt.locality, payload


def register_payload(rt, name, n):
    arr = np.arange(n, dtype=np.float64)
    gid = agas.default().register({"x": arr}, name=name)
    return list(_gid_key(gid))


def fetch_by_name(rt, name):
    from repro.net import remote

    return remote.fetch(name)


def counter_value(rt, name):
    from repro.core import counters

    return counters.default().get_value(name)


def unregister_by_name(rt, name):
    a = agas.default()
    a.unregister(a.gid_of(name))


# ------------------------------------------------------------------ fixture
@pytest.fixture(scope="module")
def net(rt):
    with rnet.running(3, pools={"default": 4, "io": 1}) as n:
        yield n


# -------------------------------------------------------------------- tests
def test_run_on_round_trip_zero_copy_array(net):
    arr = np.arange(1024, dtype=np.float32)
    loc, back = rnet.run_on(1, echo_locality, arr).get(timeout=60)
    assert loc == 1
    np.testing.assert_array_equal(back, arr)


def test_apply_remote_object_on_worker(net):
    """Acceptance path: action registered at locality 0, object living on
    locality 1, result future completes on the caller."""
    key = rnet.run_on(1, register_payload, "net-test/obj1", 16).get(timeout=60)
    gid = GID(*key)
    assert gid.locality == 1
    got = rnet.apply_remote(tree_scale_sum, gid, 3).get(timeout=60)
    assert got == pytest.approx(float(np.arange(16).sum()) * 3)
    # by symbolic name, through the root name index
    got2 = rnet.apply_remote(tree_scale_sum, "net-test/obj1", 2).get(timeout=60)
    assert got2 == pytest.approx(float(np.arange(16).sum()) * 2)


def test_core_parcel_apply_is_locality_transparent(net):
    """`repro.core.parcel.apply` reaches remote objects via the installed
    route — no spelling change at existing call sites."""
    key = rnet.run_on(2, register_payload, "net-test/obj2", 8).get(timeout=60)
    fut = parcel.apply(tree_scale_sum, GID(*key), 10)
    assert fut.get(timeout=60) == pytest.approx(float(np.arange(8).sum()) * 10)


def test_remote_exception_propagates(net):
    key = rnet.run_on(1, register_payload, "net-test/obj3", 4).get(timeout=60)
    fut = rnet.apply_remote(raise_value_error, GID(*key), "boom-net")
    with pytest.raises(ValueError, match="boom-net"):
        fut.get(timeout=60)


def test_unknown_gid_fails_fast(net):
    fut = rnet.apply_remote(tree_scale_sum, GID(1, 987654321), 1)
    with pytest.raises(rnet.UnknownGid):
        fut.get(timeout=60)


def test_migrate_remote_and_stale_cache_self_heals(net):
    key = rnet.run_on(1, register_payload, "net-test/mig", 32).get(timeout=60)
    gid = GID(*key)
    # warm the root's resolution path at the old owner
    assert rnet.apply_remote(tree_scale_sum, gid, 1).get(timeout=60) == \
        pytest.approx(float(np.arange(32).sum()))
    gen = rnet.migrate_remote(gid, 2)
    assert gen >= 1
    # stale caches (ours was invalidated; use the name path + worker 1's
    # cache via forwarding) still resolve to the new owner
    got = rnet.apply_remote(tree_scale_sum, gid, 2).get(timeout=60)
    assert got == pytest.approx(float(np.arange(32).sum()) * 2)
    state = rnet.run_on(1, fetch_by_name, "net-test/mig").get(timeout=60)
    np.testing.assert_array_equal(state["x"], np.arange(32, dtype=np.float64))
    # and the object is really gone from locality 1: a direct parcel to it
    # comes back UnknownGid (the generation-invalidation signal)
    with pytest.raises(rnet.UnknownGid):
        net.send_parcel(1, tree_scale_sum._action_name, tuple(key),
                        (1,)).get(timeout=60)


def test_worker_to_worker_via_root_switch(net):
    """locality 1 fetches an object on locality 2: frames hop through the
    root's forwarding path."""
    rnet.run_on(2, register_payload, "net-test/fwd", 5).get(timeout=60)
    before = net.c_forwarded.get_value()
    state = rnet.run_on(1, fetch_by_name, "net-test/fwd").get(timeout=60)
    np.testing.assert_array_equal(state["x"], np.arange(5, dtype=np.float64))
    assert net.c_forwarded.get_value() > before


def test_query_counters_remote_snapshot(net):
    got = dict(rnet.query_counters(1, "/scheduler{*"))
    assert any("/tasks/executed" in k for k in got)
    assert sum(v for k, v in got.items() if k.endswith("/tasks/executed")) > 0
    # parcelport counters exist on the worker side too
    pp = dict(rnet.query_counters(2, "/net{locality#2*"))
    assert any(k.endswith("/parcels/received") for k in pp)


def test_fetch_remote_state(net):
    rnet.run_on(1, register_payload, "net-test/fetch", 6).get(timeout=60)
    state = rnet.fetch("net-test/fetch")
    np.testing.assert_array_equal(state["x"], np.arange(6, dtype=np.float64))


def test_net_counters_on_root(net):
    sent = dict(core.counters.query("/net{locality#0/peer#*}/parcels/sent"))
    recv = dict(core.counters.query("/net{locality#0/peer#*}/bytes/received"))
    assert sum(sent.values()) > 0
    assert sum(recv.values()) > 0


def test_local_dispatch_leaves_no_pending_entry(net):
    """An apply that resolves to the caller's own locality never touches
    the wire — and must not leak a slot in the pending-promise table."""
    gid = agas.default().register({"x": np.arange(3, dtype=np.float64)},
                                  name="net-test/local")
    before = len(net._pending)
    assert rnet.apply_remote(tree_scale_sum, gid, 2).get(timeout=60) == \
        pytest.approx(6.0)
    assert rnet.run_on(0, echo_locality, "home").get(timeout=60) == \
        (0, "home")
    assert len(net._pending) <= before


def test_checkpoint_by_gid_respawns_on_fresh_locality(net, tmp_path):
    """save_gid at the root pulls remote state home over the parcelport;
    restore_gid re-homes it on a different locality under the same name,
    re-published through the root AGAS table."""
    from repro.checkpoint import ckpt

    import json as _json

    key = rnet.run_on(1, register_payload, "net-test/ckpt", 12).get(timeout=60)
    # save by GID: the symbolic name must still land in agas.json (the
    # owner is asked for its record metadata)
    out = ckpt.save_gid(tmp_path, step=7, target=GID(*key))
    meta = _json.loads((out / "agas.json").read_text())
    assert meta["name"] == "net-test/ckpt"
    # kill the original: locality 1 no longer holds it
    rnet.run_on(1, unregister_by_name, "net-test/ckpt").get(timeout=60)
    step, gid = ckpt.restore_gid(tmp_path, locality=2)
    assert step == 7 and gid.locality == 2
    got = rnet.apply_remote(tree_scale_sum, "net-test/ckpt", 1).get(timeout=60)
    assert got == pytest.approx(float(np.arange(12).sum()))
    state = rnet.fetch(gid)
    np.testing.assert_array_equal(state["x"], np.arange(12, dtype=np.float64))
