"""Leak-proof bootstrap: worker processes are reaped even when the code
between bootstrap and shutdown raises (``net.running`` context manager and
the ``net_factory`` fixture).  A stranded worker would idle for the test
runner's lifetime and poison every later bootstrap (the one-runtime-per-
process invariant), so teardown-on-failure is a correctness property."""

import pytest

from repro import net as rnet


def test_running_reaps_workers_when_body_raises(rt):
    procs = []
    with pytest.raises(RuntimeError, match="boom"):
        with rnet.running(3) as net:
            procs = list(net._procs.values())
            assert len(procs) == 2 and all(p.is_alive() for p in procs)
            raise RuntimeError("boom")
    assert rnet.current() is None, "runtime must be uninstalled"
    for p in procs:
        p.join(timeout=30)
    assert all(not p.is_alive() for p in procs), "workers must be reaped"


def test_net_factory_tears_down_between_tests(rt, net_factory):
    net = net_factory(2)
    assert rnet.current() is net and net.n_localities == 2
    assert rnet.run_on(1, _probe).get(timeout=60) == 1
    # no explicit shutdown: the fixture's ExitStack owns it — verified by
    # the next test being able to bootstrap at all


def test_bootstrap_after_factory_teardown(rt):
    assert rnet.current() is None, "previous fixture leaked its runtime"
    with rnet.running(1) as net:  # degenerate single-locality bootstrap
        assert net.is_root() and net.n_localities == 1 and not net._procs
    assert rnet.current() is None


def _probe(rt_remote):
    return rt_remote.locality
