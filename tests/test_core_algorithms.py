"""C++17-style parallel algorithms: every policy agrees with seq (HPX P6)."""
import operator

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import algorithms as alg
from repro.core.executor import (MeshExecutor, mesh_policy, par, par_task,
                                 seq, seq_task, vec)
from repro.core.future import Future

floats = st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                  min_size=1, max_size=200)
ints = st.lists(st.integers(-1000, 1000), min_size=1, max_size=200)


@settings(max_examples=20, deadline=None)
@given(ints)
def test_reduce_par_matches_seq(rt, xs):
    assert alg.reduce(par, xs) == alg.reduce(seq, xs) == sum(xs)


@settings(max_examples=20, deadline=None)
@given(ints)
def test_sort_par_matches_sorted(rt, xs):
    assert alg.sort(par, xs) == sorted(xs)
    assert list(np.asarray(alg.sort(vec, xs))) == sorted(xs)


@settings(max_examples=20, deadline=None)
@given(ints)
def test_transform_policies_agree(rt, xs):
    f = lambda x: 3 * x + 1
    s = alg.transform(seq, xs, f)
    p = alg.transform(par, xs, f)
    v = list(np.asarray(alg.transform(vec, jnp.asarray(xs), f)))
    assert s == p == v


@settings(max_examples=20, deadline=None)
@given(ints)
def test_scans_match_numpy(rt, xs):
    inc = alg.inclusive_scan(seq, xs)
    assert inc == list(np.cumsum(xs))
    exc = alg.exclusive_scan(seq, xs, init=0)
    assert exc == [0] + list(np.cumsum(xs))[:-1]
    vinc = list(np.asarray(alg.inclusive_scan(vec, jnp.asarray(xs))))
    assert vinc == inc


@settings(max_examples=20, deadline=None)
@given(ints)
def test_count_if_and_predicates(rt, xs):
    even = lambda x: x % 2 == 0
    n = alg.count_if(par, xs, even)
    assert n == sum(1 for x in xs if even(x))
    assert alg.any_of(par, xs, even) == (n > 0)
    assert alg.all_of(par, xs, even) == (n == len(xs))


def test_transform_reduce(rt):
    xs = list(range(100))
    assert alg.transform_reduce(par, xs, lambda x: x * x) == sum(x * x for x in xs)
    assert int(alg.transform_reduce(vec, jnp.arange(100), lambda x: x * x)) == sum(
        x * x for x in xs)


def test_for_each_side_effects(rt):
    out = []
    lock_free = [0] * 50
    alg.for_each(seq, range(50), lambda i: lock_free.__setitem__(i, i * 2))
    assert lock_free == [2 * i for i in range(50)]


def test_chunk_size_override(rt):
    xs = list(range(1000))
    assert alg.reduce(par.with_chunk_size(10), xs) == sum(xs)


# ---------------------------------------------------- cross-policy properties
def _mesh_pol():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    return mesh_policy(mesh)


POLICIES = [
    ("par", lambda: par),
    ("par_chunked", lambda: par.with_(chunk_size=3)),
    ("par_task", lambda: par_task),
    ("seq_task", lambda: seq_task),
    ("vec", lambda: vec),
    ("mesh", _mesh_pol),
]


def _val(x):
    """Materialize a policy result (Future under task policies, jnp array
    under vec/mesh, list under host) into comparable python values."""
    if isinstance(x, Future):
        x = x.get(timeout=300)
    if x is None or isinstance(x, (bool, int, float)):
        return x
    if isinstance(x, (list, tuple)):
        return [float(v) for v in x]
    arr = np.asarray(x)
    return float(arr) if arr.ndim == 0 else [float(v) for v in arr.tolist()]


@pytest.mark.parametrize("name,mk", POLICIES)
@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(-50, 50), min_size=0, max_size=60))
def test_every_algorithm_agrees_with_seq_oracle(rt, name, mk, xs):
    pol = mk()
    fn = lambda x: 3 * x + 1
    even = lambda x: x % 2 == 0
    assert _val(alg.transform(pol, xs, fn)) == _val(alg.transform(seq, xs, fn))
    assert _val(alg.reduce(pol, xs)) == float(sum(xs))
    assert _val(alg.transform_reduce(pol, xs, fn)) == float(sum(map(fn, xs)))
    assert _val(alg.sort(pol, xs)) == [float(v) for v in sorted(xs)]
    assert _val(alg.count_if(pol, xs, even)) == sum(1 for x in xs if even(x))
    assert _val(alg.all_of(pol, xs, even)) == all(even(x) for x in xs)
    assert _val(alg.any_of(pol, xs, even)) == any(even(x) for x in xs)
    assert _val(alg.copy(pol, xs)) == [float(v) for v in xs]
    assert _val(alg.inclusive_scan(pol, xs)) == _val(alg.inclusive_scan(seq, xs))
    assert _val(alg.exclusive_scan(pol, xs, init=7)) == _val(
        alg.exclusive_scan(seq, xs, init=7))


@pytest.mark.parametrize("name,mk", POLICIES)
@pytest.mark.parametrize("xs", [[], [4]], ids=["empty", "one"])
def test_edge_inputs_agree(rt, name, mk, xs):
    pol = mk()
    fn = lambda x: x * 2
    assert _val(alg.transform(pol, xs, fn)) == [float(fn(x)) for x in xs]
    assert _val(alg.reduce(pol, xs, init=5)) == float(5 + sum(xs))
    assert _val(alg.sort(pol, xs)) == [float(x) for x in xs]
    assert _val(alg.inclusive_scan(pol, xs)) == [float(v) for v in np.cumsum(xs)]
    # C++ semantics: an exclusive scan over an empty range writes nothing
    assert _val(alg.exclusive_scan(pol, xs, init=2)) == ([2.0] if xs else [])
    assert _val(alg.count_if(pol, xs, lambda x: x > 0)) == len(xs)
    assert _val(alg.all_of(pol, xs, lambda x: x > 0)) is True  # vacuous on []
    assert _val(alg.any_of(pol, xs, lambda x: x > 0)) is bool(xs)


# -------------------------------------------------------- par_task two-way
def test_par_task_returns_futures(rt):
    xs = list(range(64))
    for res in (alg.transform(par_task, xs, lambda x: x + 1),
                alg.reduce(par_task, xs),
                alg.sort(par_task, xs),
                alg.inclusive_scan(par_task, xs),
                alg.exclusive_scan(par_task, xs),
                alg.count_if(par_task, xs, lambda x: x % 3 == 0),
                alg.all_of(par_task, xs, lambda x: x >= 0),
                alg.for_each(par_task, xs, lambda x: None),
                alg.copy(par_task, xs)):
        assert isinstance(res, Future), res
        res.get(timeout=300)
    # eager policies return plain values
    assert not isinstance(alg.reduce(par, xs), Future)
    assert not isinstance(alg.transform(vec, xs, lambda x: x), Future)


def test_task_futures_carry_exceptions(rt):
    def boom(x):
        raise RuntimeError("body failed")

    f = alg.transform(par_task, [1, 2, 3], boom)
    assert isinstance(f, Future)
    with pytest.raises(RuntimeError, match="body failed"):
        f.get(timeout=60)


# ------------------------------------------------- scans with generic ops
GENERIC_OPS = [("mul", operator.mul), ("min", jnp.minimum), ("max", jnp.maximum)]


@pytest.mark.parametrize("pname,mk", [("par", lambda: par), ("vec", lambda: vec),
                                      ("mesh", _mesh_pol)])
@pytest.mark.parametrize("oname,op", GENERIC_OPS)
def test_scans_generic_ops_match_seq(rt, pname, mk, oname, op):
    xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    pol = mk()
    assert _val(alg.inclusive_scan(pol, xs, op=op)) == pytest.approx(
        _val(alg.inclusive_scan(seq, xs, op=op)))
    assert _val(alg.exclusive_scan(pol, xs, init=2.0, op=op)) == pytest.approx(
        _val(alg.exclusive_scan(seq, xs, init=2.0, op=op)))
    assert _val(alg.reduce(pol, xs, init=2.0, op=op)) == pytest.approx(
        _val(alg.reduce(seq, xs, init=2.0, op=op)))


def test_exclusive_scan_float_init_over_int_data_promotes(rt):
    # seq oracle: [0.5, 1.5, 3.5] — vec must promote, never truncate init
    want = [0.5, 1.5, 3.5]
    assert alg.exclusive_scan(seq, [1, 2, 3], init=0.5) == want
    assert _val(alg.exclusive_scan(vec, [1, 2, 3], init=0.5)) == pytest.approx(want)
    assert _val(alg.exclusive_scan(_mesh_pol(), [1, 2, 3], init=0.5)) == pytest.approx(want)


def test_batched_elements_agree_with_seq_oracle(rt):
    """Elements that are arrays (shape (3,)): the add fast paths must fold
    along axis 0, not collapse the element dimension."""
    rng = np.random.default_rng(5)
    rows = rng.standard_normal((6, 3)).astype(np.float32)
    want_red = np.asarray(alg.reduce(seq, list(rows), init=0.0))
    want_inc = np.stack(alg.inclusive_scan(seq, list(rows)))
    for pol in (vec, _mesh_pol()):
        got_red = np.asarray(alg.reduce(pol, rows, init=0.0))
        assert got_red.shape == (3,)
        np.testing.assert_allclose(got_red, want_red, rtol=1e-5)
        got_inc = np.asarray(alg.inclusive_scan(pol, rows))
        assert got_inc.shape == (6, 3)
        np.testing.assert_allclose(got_inc, want_inc, rtol=1e-5)
        got_exc = np.asarray(alg.exclusive_scan(pol, rows, init=0.0))
        want_exc = np.stack([np.zeros(3, np.float32)] + list(want_inc[:-1]))
        assert got_exc.shape == (6, 3)
        np.testing.assert_allclose(got_exc, want_exc, rtol=1e-5)


def test_task_combine_and_vec_offload_respect_bound_pool(rt):
    """A policy bound to a named pool keeps *all* its work there: the task
    combine continuation and the vec dispatch both land on that pool."""
    from repro.core import counters

    def executed(pool):
        return counters.get_value(f"/scheduler{{{pool}}}/tasks/executed")

    io_ex = rt.get_executor("io", fallback="default")
    before = executed("io")
    res = alg.sort(par_task.on(io_ex), [3, 1, 2]).get(timeout=60)
    assert res == [1, 2, 3]
    rt.drain(timeout=30)
    after_task = executed("io")
    assert after_task > before + 1  # chunks AND the combine ran on io
    out = alg.transform(vec.on(io_ex), np.arange(8.0), lambda x: x * 2)
    assert list(np.asarray(out)) == [2.0 * i for i in range(8)]
    assert executed("io") > after_task  # vec dispatch offloaded to io


def test_reduce_non_commutative_op_preserves_order(rt):
    """Associative but non-commutative op (batched matmul): the vec/mesh
    tree-fold must combine adjacent pairs, matching the seq fold order."""
    rng = np.random.default_rng(3)
    for n in (2, 3, 5, 8):  # even and odd lengths hit both fold branches
        mats = [rng.standard_normal((2, 2)).astype(np.float32) for _ in range(n)]
        want = np.eye(2, dtype=np.float32)
        for m in mats:
            want = want @ m
        got = alg.reduce(vec, np.stack(mats), init=jnp.eye(2), op=jnp.matmul)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4), n
        got_mesh = alg.reduce(_mesh_pol(), np.stack(mats), init=jnp.eye(2),
                              op=jnp.matmul)
        np.testing.assert_allclose(np.asarray(got_mesh), want, rtol=2e-4)


def test_seq_on_executor_stays_sequenced(rt):
    """HPX seq.on(exec): still sequenced, just on that executor — bodies
    must observe in-order execution even when bound to a pool."""
    out = []
    pol = seq.on(rt.get_executor("default")).with_(chunk_size=5)
    alg.for_each(pol, range(100), out.append)
    assert out == list(range(100))
    # order-sensitive associative op: string concat must stay in order
    letters = [chr(ord("a") + i % 26) for i in range(60)]
    assert alg.reduce(pol, letters, init="") == "".join(letters)


def test_vec_scan_non_traceable_op_is_loud(rt):
    host_only = lambda a, b: a if float(a) > float(b) else b  # concretizes
    with pytest.raises(ValueError, match="vec/mesh"):
        alg.inclusive_scan(vec, [1.0, 2.0, 3.0], op=host_only)
    with pytest.raises(ValueError, match="vec/mesh"):
        alg.exclusive_scan(vec, [1.0, 2.0, 3.0], init=0.0, op=host_only)
    with pytest.raises(ValueError, match="vec/mesh"):
        alg.reduce(vec, [1.0, 2.0, 3.0], op=host_only)
    # shape-changing op: combines slices but not elementwise — also loud
    with pytest.raises(ValueError, match="elementwise"):
        alg.reduce(vec, [1.0, 2.0, 3.0, 4.0], op=lambda a, b: jnp.stack([a, b]))


# ----------------------------------------------------------- vec for_each
def test_for_each_vec_vectorizes_traceable_bodies(rt):
    # module contract: traceable bodies lower through jax.vmap (no host loop)
    calls = []

    def body(x):
        calls.append(1)  # traced exactly once, not once per element
        return x * 2.0

    assert alg.for_each(vec, np.arange(64.0), body) is None
    assert len(calls) == 1, "body was traced, not looped per element"


def test_for_each_vec_non_traceable_raises(rt):
    out = []
    with pytest.raises(ValueError, match="seq/par"):
        alg.for_each(vec, [1, 2, 3], lambda x: out.append(int(x)))
    assert out == []  # nothing silently executed sequentially


# ------------------------------------------- HPX staples: fill/min/max
@pytest.mark.parametrize("name,mk", POLICIES)
@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(-50, 50), min_size=1, max_size=60))
def test_staples_agree_with_seq_oracle(rt, name, mk, xs):
    pol = mk()
    assert _val(alg.min_element(pol, xs)) == float(min(xs))
    assert _val(alg.max_element(pol, xs)) == float(max(xs))
    host_data = list(xs)
    filled = alg.fill(pol, host_data if name not in ("vec", "mesh")
                      else jnp.asarray(xs), 3)
    assert _val(filled) == [3.0] * len(xs)


def test_fill_mutates_host_sequences_in_place(rt):
    xs = list(range(10))
    out = alg.fill(par, xs, -1)
    assert out is xs and xs == [-1] * 10
    # vec: arrays are immutable — a new filled array, dtype preserved
    arr = jnp.arange(10)
    out = alg.fill(vec, arr, 4)
    assert out.dtype == arr.dtype and list(np.asarray(out)) == [4] * 10


def test_extrema_of_empty_range_raise(rt):
    for pol in (seq, par, vec):
        with pytest.raises(ValueError, match="empty"):
            alg.min_element(pol, [])
        with pytest.raises(ValueError, match="empty"):
            alg.max_element(pol, [])


def test_staples_two_way_futures(rt):
    xs = [5, 1, 9, 3]
    f_min = alg.min_element(par_task, xs)
    f_fill = alg.fill(par_task, list(xs), 0)
    assert isinstance(f_min, Future) and isinstance(f_fill, Future)
    assert f_min.get(timeout=60) == 1
    assert f_fill.get(timeout=60) == [0] * 4
