"""C++17-style parallel algorithms: par/vec/seq agree (HPX P6)."""
import operator

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import algorithms as alg
from repro.core.executor import par, seq, vec

floats = st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                  min_size=1, max_size=200)
ints = st.lists(st.integers(-1000, 1000), min_size=1, max_size=200)


@settings(max_examples=20, deadline=None)
@given(ints)
def test_reduce_par_matches_seq(rt, xs):
    assert alg.reduce(par, xs) == alg.reduce(seq, xs) == sum(xs)


@settings(max_examples=20, deadline=None)
@given(ints)
def test_sort_par_matches_sorted(rt, xs):
    assert alg.sort(par, xs) == sorted(xs)
    assert list(np.asarray(alg.sort(vec, xs))) == sorted(xs)


@settings(max_examples=20, deadline=None)
@given(ints)
def test_transform_policies_agree(rt, xs):
    f = lambda x: 3 * x + 1
    s = alg.transform(seq, xs, f)
    p = alg.transform(par, xs, f)
    v = list(np.asarray(alg.transform(vec, jnp.asarray(xs), f)))
    assert s == p == v


@settings(max_examples=20, deadline=None)
@given(ints)
def test_scans_match_numpy(rt, xs):
    inc = alg.inclusive_scan(seq, xs)
    assert inc == list(np.cumsum(xs))
    exc = alg.exclusive_scan(seq, xs, init=0)
    assert exc == [0] + list(np.cumsum(xs))[:-1]
    vinc = list(np.asarray(alg.inclusive_scan(vec, jnp.asarray(xs))))
    assert vinc == inc


@settings(max_examples=20, deadline=None)
@given(ints)
def test_count_if_and_predicates(rt, xs):
    even = lambda x: x % 2 == 0
    n = alg.count_if(par, xs, even)
    assert n == sum(1 for x in xs if even(x))
    assert alg.any_of(par, xs, even) == (n > 0)
    assert alg.all_of(par, xs, even) == (n == len(xs))


def test_transform_reduce(rt):
    xs = list(range(100))
    assert alg.transform_reduce(par, xs, lambda x: x * x) == sum(x * x for x in xs)
    assert int(alg.transform_reduce(vec, jnp.arange(100), lambda x: x * x)) == sum(
        x * x for x in xs)


def test_for_each_side_effects(rt):
    out = []
    lock_free = [0] * 50
    alg.for_each(seq, range(50), lambda i: lock_free.__setitem__(i, i * 2))
    assert lock_free == [2 * i for i in range(50)]


def test_chunk_size_override(rt):
    xs = list(range(1000))
    assert alg.reduce(par.with_chunk_size(10), xs) == sum(xs)
