"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,H,KV,Dh", [
    (1, 128, 4, 4, 64),   # MHA
    (2, 256, 4, 2, 64),   # GQA
    (1, 384, 8, 1, 32),   # MQA, odd seq multiples
    (2, 200, 4, 2, 64),   # needs padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, Dh, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, Dh), dtype)
    o = ops.flash_attention(q, k, v, causal=causal)
    e = ref.mha(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(e, np.float32), atol=_tol(dtype) * 4)


def test_flash_attention_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    o = ops.flash_attention(q, k, v, causal=True, window=64)
    e = ref.mha(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e), atol=1e-4)


@pytest.mark.parametrize("B,T,H,KV,Dh,length", [
    (2, 512, 4, 2, 64, 300),
    (1, 1024, 8, 8, 32, 1024),
    (3, 300, 4, 1, 64, 17),   # padding + MQA + short fill
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, T, H, KV, Dh, length, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, Dh), dtype)
    o = ops.decode_attention(q, k, v, jnp.asarray(length))
    e = ref.decode_mha(q, k, v, length=length)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(e, np.float32), atol=_tol(dtype) * 4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_per_row_lengths(dtype):
    """The seed bug: one scalar length masked every row, so slots at
    different fill depths attended over stale/zero KV.  A (B,) vector must
    match the oracle row-by-row."""
    B, T, H, KV, Dh = 4, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, Dh), dtype)
    lens = jnp.asarray([1, 17, 100, 256], jnp.int32)
    o = ops.decode_attention(q, k, v, lens)
    e = ref.decode_mha(q, k, v, length=lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(e, np.float32), atol=_tol(dtype) * 4)
    # divergence is real: the scalar path at max(lens) differs on short rows
    o_scalar = ops.decode_attention(q, k, v, jnp.asarray(256))
    assert not np.allclose(np.asarray(o, np.float32)[0],
                           np.asarray(o_scalar, np.float32)[0])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,Dh,page,maxp", [
    (3, 4, 2, 64, 32, 8),   # GQA
    (2, 8, 8, 32, 16, 4),   # MHA, small pages
    (1, 8, 1, 64, 64, 4),   # MQA
])
def test_paged_decode_attention_matches_oracle(B, H, KV, Dh, page, maxp, dtype):
    """Paged kernel walking shuffled per-request page lists == dense oracle."""
    T = page * maxp
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, Dh), dtype)
    rng = np.random.default_rng(0)
    P = B * maxp + 3  # pool with spare pages; page 0 reserved
    perm = 1 + rng.permutation(P - 1)[: B * maxp].reshape(B, maxp)
    k_pages = np.zeros((P, page, KV, Dh), np.float32)
    v_pages = np.zeros((P, page, KV, Dh), np.float32)
    for b in range(B):
        for j in range(maxp):
            k_pages[perm[b, j]] = np.asarray(k[b, j * page:(j + 1) * page], np.float32)
            v_pages[perm[b, j]] = np.asarray(v[b, j * page:(j + 1) * page], np.float32)
    lens = jnp.asarray(rng.integers(1, T + 1, size=B), jnp.int32)
    o = ops.paged_decode_attention(q, jnp.asarray(k_pages, dtype),
                                   jnp.asarray(v_pages, dtype),
                                   jnp.asarray(perm, jnp.int32), lens)
    e = ref.decode_mha(q, k, v, length=lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(e, np.float32), atol=_tol(dtype) * 4)


def test_gather_paged_kv_roundtrip():
    P, page, KV, Dh, B, maxp = 10, 16, 2, 32, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(10), 2)
    k_pages = jax.random.normal(ks[0], (P, page, KV, Dh))
    v_pages = jax.random.normal(ks[1], (P, page, KV, Dh))
    pt = jnp.asarray([[1, 3, 5, 7], [2, 4, 6, 8]], jnp.int32)
    kg, vg = ops.gather_paged_kv(k_pages, v_pages, pt)
    assert kg.shape == (B, maxp * page, KV, Dh)
    np.testing.assert_array_equal(np.asarray(kg[0, :page]), np.asarray(k_pages[1]))
    np.testing.assert_array_equal(np.asarray(vg[1, page:2 * page]),
                                  np.asarray(v_pages[4]))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 128, 2, 16, 1, 16, 32),
    (2, 96, 4, 16, 2, 32, 32),   # GQA-style groups + padding (96 % 32 == 0)
    (1, 100, 2, 8, 2, 16, 64),   # non-divisible → pad
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    y = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    e, _ = ref.ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(e, np.float32),
                               atol=_tol(dtype) * 8, rtol=1e-2)


@pytest.mark.parametrize("B,S,W", [(1, 256, 128), (2, 130, 100), (1, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(B, S, W, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W)) * 0.1).astype(dtype)
    h = ops.rglru_scan(a, b)
    e = ref.rglru(a, b)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(e, np.float32), atol=_tol(dtype) * 4)


@pytest.mark.parametrize("N", [1000, 65536, 70000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_triad_sweep(N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    a = jax.random.normal(ks[0], (N,), dtype)
    b = jax.random.normal(ks[1], (N,), dtype)
    o = ops.stream_triad(a, b, 3.0)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref.triad(a, b, 3.0), np.float32),
                               atol=_tol(dtype))


def test_flash_attention_trainable_grads_match_oracle():
    """custom_vjp kernel path: grads == jax.grad of the pure-jnp oracle."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention_trainable(q, k, v, True, 0) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.mha(q, k, v, causal=True) ** 2)

    l1, g1 = jax.value_and_grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    l2, g2 = jax.value_and_grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(l1) - float(l2)) < 1e-2
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
