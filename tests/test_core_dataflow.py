"""Futurization / dataflow DAG execution (HPX P1)."""
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as core
from repro.core.dataflow import TaskGraph, dataflow, futurize
from repro.core.future import make_ready_future


def test_dataflow_waits_for_args(rt):
    a = core.spawn(lambda: 2)
    b = core.spawn(lambda: 3)
    c = dataflow(lambda x, y: x * y, a, b)
    assert c.get() == 6


def test_dataflow_nested_containers(rt):
    a = core.spawn(lambda: 1)
    c = dataflow(lambda d: d["x"] + d["y"][0], {"x": a, "y": [make_ready_future(2)]})
    assert c.get() == 3


def test_futurize_decorator(rt):
    @futurize
    def add(a, b):
        return a + b

    assert add(add(1, 2), add(3, 4)).get() == 10


def test_taskgraph_topological(rt):
    g = TaskGraph()
    g.add("a", lambda: 1)
    g.add("b", lambda x: x + 1, deps=["a"])
    g.add("c", lambda x: x * 10, deps=["a"])
    g.add("d", lambda x, y: x + y, deps=["b", "c"])
    assert g.run()["d"].get() == 12


def test_taskgraph_rejects_unknown_dep(rt):
    g = TaskGraph()
    with pytest.raises(ValueError):
        g.add("x", lambda y: y, deps=["missing"])


def test_taskgraph_rejects_duplicate(rt):
    g = TaskGraph()
    g.add("a", lambda: 1)
    with pytest.raises(ValueError):
        g.add("a", lambda: 2)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=1, max_size=40))
def test_dataflow_tree_reduction_matches_sum(xs):
    """Property: a random dataflow reduction tree == plain sum."""
    rt = core.get_runtime()
    futs = [make_ready_future(x) for x in xs]
    while len(futs) > 1:
        nxt = []
        for i in range(0, len(futs) - 1, 2):
            nxt.append(dataflow(lambda a, b: a + b, futs[i], futs[i + 1]))
        if len(futs) % 2:
            nxt.append(futs[-1])
        futs = nxt
    assert futs[0].get() == sum(xs)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 30), st.data())
def test_random_dag_executes_in_dependency_order(n, data):
    """Property: every node observes its dependencies' results (values
    propagate along a random DAG without races)."""
    g = TaskGraph()
    g.add("n0", lambda: 1)
    for i in range(1, n):
        deps = data.draw(st.lists(
            st.sampled_from([f"n{j}" for j in range(i)]),
            min_size=1, max_size=min(i, 4), unique=True))
        g.add(f"n{i}", lambda *vals: sum(vals) + 1, deps=deps)
    results = {k: f.get() for k, f in g.run().items()}
    assert all(v >= 1 for v in results.values())
    assert results["n0"] == 1
