"""Serving across localities: Router.over_localities places one engine per
OS process; dispatch is least-loaded over local + gossiped remote loads."""

import numpy as np
import pytest

import repro.core as core
from repro import net as rnet
from repro.serve.engine import SamplingParams, ServeConfig
from repro.serve.router import RemoteEngine, Router


@pytest.fixture(scope="module")
def net_router(rt):
    pools = {"default": 4, "prefill": 2, "io": 1}
    with rnet.running(2, pools=pools, worker_pools=pools) as net:
        scfg = ServeConfig(max_batch=2, cache_len=64, max_new_tokens=6)
        router = Router.over_localities(net, "qwen25_3b", scfg, smoke=True,
                                        plan="serve")
        yield net, router


def test_both_localities_serve(net_router):
    net, router = net_router
    assert isinstance(router.engines[1], RemoteEngine)
    rng = np.random.default_rng(0)
    futures = [router.submit(
        rng.integers(1, 512, size=rng.integers(4, 20)).tolist())
        for _ in range(8)]
    outs = [f.get(timeout=600) for f in futures]
    assert all(len(o) == 7 for o in outs)  # max_new + prefill token
    local = dict(core.counters.query("/serve{engine#0}/tokens/generated"))
    remote = dict(rnet.query_counters(1, "/serve{engine#1}/tokens/generated"))
    assert sum(local.values()) > 0, "locality 0 must serve"
    assert sum(remote.values()) > 0, "locality 1 must serve"
    # gossip came back on result frames
    assert router.engines[1]._gossip >= 0.0
    assert router.engines[1]._inflight == 0


def test_remote_greedy_matches_local_engine(net_router):
    """Replicas build identical params from the shared seed: a greedy
    prompt must decode identically on either locality."""
    net, router = net_router
    prompt = list(range(1, 11))
    local = router.engines[0].submit(prompt).get(timeout=600)
    remote = router.engines[1].submit(prompt).get(timeout=600)
    assert local == remote


def test_streaming_crosses_localities_via_relay(net_router):
    """Streams are no longer per-process: the token relay carries indexed
    token parcels from a remote engine into the client-side channel,
    exactly once each."""
    net, router = net_router
    ch, fut = router.submit_stream(list(range(1, 8)))
    toks = list(ch)
    assert toks == fut.get(timeout=600)
    # force the remote engine explicitly — the relay must deliver the
    # stream across the parcelport with zero duplicates
    before = dict(core.counters.query("/serve{relay}/tokens/duplicates"))
    from repro.core.future import Channel

    ch2 = Channel()
    fut2 = router.engines[1].submit(list(range(1, 8)), stream=ch2)
    toks2 = list(ch2)
    assert toks2 == fut2.get(timeout=600)
    assert toks2 == toks  # greedy parity holds through the relay
    after = dict(core.counters.query("/serve{relay}/tokens/duplicates"))
    assert sum(after.values()) == sum(before.values())


def test_remote_sampling_params_cross_the_wire(net_router):
    net, router = net_router
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.9)
    out = router.engines[1].submit(list(range(1, 9)),
                                   sampling=sp).get(timeout=600)
    assert len(out) == 7
