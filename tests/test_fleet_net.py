"""Fleet control plane over real localities: elastic grow/retire, SLO
admission gating, zero-drop live engine migration, router failover onto a
healthy replica when a locality dies, and the fault-tolerant counter sweep.

Tests in this module share one running fleet and are order-dependent (the
topology evolves: grow -> migrate -> retire -> crash)."""

import time

import numpy as np
import pytest

import repro.core as core
from repro import net as rnet
from repro.core.future import Channel
from repro.fleet import (AdmissionController, grow_engine, migrate_engine,
                         retire_engine)
from repro.serve.engine import ServeConfig
from repro.serve.router import (TIER_BATCH, TIER_INTERACTIVE, RemoteEngine,
                                Router)

pytestmark = pytest.mark.usefixtures("rt")


def _relay_total(name: str) -> float:
    return sum(v for _, v in
               core.counters.query(f"/serve{{relay}}/tokens/{name}"))


@pytest.fixture(scope="module")
def fleet(rt):
    pools = {"default": 4, "prefill": 2, "io": 1}
    with rnet.running(2, pools=pools, worker_pools=pools) as net:
        scfg = ServeConfig(max_batch=2, cache_len=96, max_new_tokens=24)
        router = Router.over_localities(net, "qwen25_3b", scfg, smoke=True,
                                        plan="serve")
        yield net, router


def _prompts(n, rng=None):
    rng = rng or np.random.default_rng(7)
    return [rng.integers(1, 512, size=rng.integers(4, 16)).tolist()
            for _ in range(n)]


def test_grow_engine_joins_running_fleet(fleet):
    net, router = fleet
    before = set(net.live_ids())
    e = grow_engine(net, router, tier=TIER_BATCH)
    assert e.locality not in before
    assert net.is_live(e.locality)
    assert router.engine(e.name) is e
    assert router.tier_of(e.name) == TIER_BATCH
    # the newcomer actually serves
    out = e.submit(list(range(1, 9))).get(timeout=600)
    assert len(out) == 25  # max_new + prefill token
    # and it decodes identically to the seed replicas (greedy parity)
    assert out == router.engines[0].submit(list(range(1, 9))).get(timeout=600)


def test_slo_routing_prefers_tier(fleet):
    net, router = fleet
    interactive = router.engines[1]  # the loc-1 remote
    router.set_tier(interactive.name, TIER_INTERACTIVE)
    name = f"/serve{{router}}/dispatch/{interactive.name}"
    before = dict(core.counters.query(name))[name]
    futs = [router.submit(p, slo=TIER_INTERACTIVE) for p in _prompts(4)]
    for f in futs:
        assert len(f.get(timeout=600)) == 25
    after = dict(core.counters.query(name))[name]
    assert after - before == 4  # every interactive submit hit its tier


def test_admission_gate_parks_then_releases_batch(fleet):
    net, router = fleet
    sig = {"occ": 0.95}
    router.admission = AdmissionController(lambda: sig["occ"],
                                           high=0.85, low=0.60)
    assert not router.admission.allow()  # gate closed by synthetic signal
    futs = [router.submit(p, slo=TIER_BATCH) for p in _prompts(3)]
    assert router.gated_depth() == 3
    assert not any(f.is_ready() for f in futs)
    sig["occ"] = 0.10  # pressure gone: next release tick drains the park
    assert router.release_gated() == 3
    assert router.gated_depth() == 0
    for f in futs:
        assert len(f.get(timeout=600)) == 25
    router.admission = None


def test_live_migration_zero_dropped_zero_duplicated(fleet):
    """The headline: move engine#1 (locality 1) to locality 2 while it is
    streaming.  Every stream must deliver exactly the tokens its future
    returns — no gap at the cutover, no duplicate — and the relay's
    duplicate counter must not move."""
    net, router = fleet
    e1 = router.engine("engine#1")
    assert isinstance(e1, RemoteEngine) and e1.locality == 1
    dest = next(e.locality for e in router.engines
                if isinstance(e, RemoteEngine) and e.locality != 1)
    dups_before = _relay_total("duplicates")

    # enough work that the cutover lands mid-generation: 8 requests on a
    # max_batch=2 engine is four full decode waves
    pairs = []
    for p in _prompts(8):
        ch = Channel()
        pairs.append((ch, e1.submit(p, stream=ch)))
    t0 = time.monotonic()
    moved = migrate_engine(net, router, "engine#1", dest)
    cutover = time.monotonic() - t0

    for ch, fut in pairs:
        out = fut.get(timeout=600)
        assert list(ch) == out  # streamed == authoritative, in order
        assert len(out) == 25
    assert e1.locality == dest
    assert _relay_total("duplicates") == dups_before
    assert moved >= 0
    mig = dict(rnet.query_counters(
        dest, "/serve{engine#1}/requests/migrated_in"))
    assert sum(mig.values()) == moved
    print(f"migrated {moved} in-flight requests in {cutover:.2f}s")

    # the engine keeps serving from its new home, same greedy stream
    out = router.engine("engine#1").submit(
        list(range(1, 9))).get(timeout=600)
    assert out == router.engines[0].submit(list(range(1, 9))).get(timeout=600)


def test_retire_engine_drains_then_removes_locality(fleet):
    net, router = fleet
    e = grow_engine(net, router)  # disposable capacity to retire
    lid = e.locality
    # park some work on it first so the drain loop has something to wait on
    futs = [e.submit(p) for p in _prompts(3)]
    for f in futs:
        f.get(timeout=600)
    retired = retire_engine(net, router, e.name)
    assert retired == lid
    assert not net.is_live(lid)
    assert all(en.name != e.name for en in router.engines
               if hasattr(en, "name"))
    # fleet still serves
    assert len(router.submit(list(range(1, 9))).get(timeout=600)) == 25


def test_failover_and_sweep_survive_locality_crash(fleet):
    """Kill a worker process outright: the router must evict its engines
    and land retried submits on a healthy replica; the counter sweep must
    report the corpse as an error marker, not raise."""
    net, router = fleet
    victim = max(e.locality for e in router.engines
                 if isinstance(e, RemoteEngine))
    doomed = [e.name for e in router.engines
              if isinstance(e, RemoteEngine) and e.locality == victim]
    router.max_failover = 4
    net._procs[victim].kill()  # simulated crash, not an orderly BYE

    deadline = time.monotonic() + 30
    while victim in net.live_ids() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert victim not in net.live_ids()

    # dead-peer sweep: explicit id list includes the corpse -> error marker
    sweep = rnet.query_counters([0, victim], "/serve*")
    assert isinstance(sweep[victim], dict) and "error" in sweep[victim]
    assert isinstance(sweep[0], list)

    # submits keep completing (failover may need a few picks to evict all
    # of the victim's engines)
    futs = [router.submit(p) for p in _prompts(6)]
    for f in futs:
        assert len(f.get(timeout=600)) == 25
    for name in doomed:
        assert name in router._dead
    evicted = dict(core.counters.query("/serve{router}/failover/evicted"))
    assert sum(evicted.values()) >= len(doomed)
