"""repro.container: partitioned vectors + segmented algorithms over a
3-locality runtime (block, cyclic and explicit layouts — including empty
and single-element segments), every algorithm checked against the
single-locality seq oracle, plus the counter-verified work-to-data claim:
``for_each`` moves ~zero element bytes while fetch-all moves them all.

Bodies/ops live at module level: segmented algorithms ship them to the
data pickled by reference."""

import itertools
import threading

import numpy as np
import pytest

# Worker localities import THIS module to resolve shipped bodies by
# reference; they don't run conftest, so the hypothesis backfill must be
# installed here before the import below (inert when the real lib exists).
from repro import _hypothesis_shim

_hypothesis_shim.install_if_missing()

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import net as rnet
from repro.core import algorithms as alg
from repro.core.executor import par, par_task, seq
from repro.core.future import Future
from repro.container import PartitionedVector, distribution as dist_mod


# ----------------------------------------------------- module-level bodies
def aff(x):
    return 3 * x + 1


def sq(x):
    return x * x


def is_even(x):
    return x % 2 == 0


def nonneg(x):
    return x >= 0


def touch(x):
    pass


def attach_probe(rt, name):
    """Runs on a worker: attach by name and read through the handle."""
    pv = PartitionedVector.attach(name)
    return [len(pv), pv.nsegments, float(pv.get(0))]


# ------------------------------------------------------------------ fixture
@pytest.fixture(scope="module")
def net(rt):
    with rnet.running(3, pools={"default": 4, "io": 1}) as n:
        yield n


_uid = itertools.count()


def mkpv(xs, distribution="block", dtype=np.float64):
    xs = np.asarray(xs, dtype=dtype)
    pv = PartitionedVector.create(f"t/c{next(_uid)}", len(xs), dtype=dtype,
                                  distribution=distribution)
    if len(xs):
        pv.set_slice(0, len(xs), xs)
    return pv


def _dists(n):
    """Block, cyclic, and an explicit layout with empty + single-element
    segments, all over 3 localities."""
    explicit = ([0, min(1, n), max(n - 1, 0)] if n else [0, 0, 0])
    return [("block", "block"), ("cyclic", "cyclic"),
            ("explicit", dist_mod.explicit(explicit, [2, 0, 1]))]


# ----------------------------------------------------- distribution geometry
@pytest.mark.parametrize("kind", ["block", "cyclic"])
def test_distribution_mapping_round_trips(kind):
    d = getattr(dist_mod, kind)(23, [0, 1, 2])
    assert d.length == 23 and sum(d.sizes) == 23
    seen = []
    for j in range(d.nsegments):
        seen.extend(d.global_indices(j).tolist())
    assert sorted(seen) == list(range(23))
    for i in (0, 1, 11, 22):
        j, loc = d.segment_of(i)
        assert d.global_indices(j)[loc] == i
    runs = d.locate_range(5, 17)
    got = np.empty(12, dtype=np.int64)
    for j, local, pos in runs:
        got[pos] = d.global_indices(j)[local]
    assert got.tolist() == list(range(5, 17))


def test_explicit_distribution_with_empty_and_single_segments():
    d = dist_mod.explicit([0, 1, 4], [2, 0, 1])
    assert d.length == 5 and d.segment_of(0) == (1, 0)
    assert d.segment_of(4) == (2, 3)
    assert d.global_indices(0).size == 0
    with pytest.raises(ValueError):
        dist_mod.explicit([1, 2], [0])  # len mismatch


# ------------------------------------------------------- creation and access
def test_create_access_and_attach_from_worker(net):
    xs = np.arange(20.0) * 2 - 5
    pv = mkpv(xs)
    assert len(pv) == 20 and pv.nsegments == 3
    assert np.array_equal(pv.to_array(), xs)
    assert pv.get(7) == xs[7] and pv[19] == xs[19]
    pv.set(3, -99.0)
    pv[4] = -100.0
    assert pv[3:6].tolist() == [-99.0, -100.0, xs[5]]
    assert pv[-1] == xs[-1]  # python-sequence negative indexing
    pv[-2] = 123.0
    assert pv.get(18) == 123.0
    with pytest.raises(ValueError, match="module level"):
        pv.fill_with(lambda idx: idx)  # loud, not a pickling traceback
    # a worker locality attaches by name and reads through AGAS
    n, nseg, first = rnet.run_on(1, attach_probe, pv.name).get(timeout=60)
    assert (n, nseg, first) == (20, 3, float(xs[0]))
    # segments really are spread over the localities
    assert sorted(pv.owners()) == [0, 1, 2]


def test_cyclic_layout_interleaves(net):
    xs = np.arange(10, dtype=np.int64)
    pv = mkpv(xs, distribution="cyclic", dtype=np.int64)
    # element i lives in segment i % 3
    assert pv.dist.segment_of(4) == (1, 1)
    assert np.array_equal(pv.to_array(), xs)
    assert pv.slice(2, 9).tolist() == list(range(2, 9))


def test_lambda_bodies_fail_loudly(net):
    pv = mkpv([1.0, 2.0])
    with pytest.raises(ValueError, match="module level"):
        alg.count_if(par, pv, lambda x: True)


# -------------------------------------------- segmented vs the seq oracle
@pytest.mark.parametrize("dname,dist", [("block", "block"),
                                        ("cyclic", "cyclic")])
@settings(max_examples=5, deadline=None)
@given(st.lists(st.integers(-50, 50), min_size=0, max_size=40))
def test_segmented_algorithms_match_seq_oracle(rt, net, dname, dist, xs):
    pv = mkpv(xs, distribution=dist)
    want_fn = [float(aff(x)) for x in xs]
    assert alg.reduce(par, pv, init=5) == float(5 + sum(xs))
    assert alg.transform_reduce(par, pv, sq, init=2) == float(
        2 + sum(sq(x) for x in xs))
    assert alg.count_if(par, pv, is_even) == sum(1 for x in xs if is_even(x))
    assert alg.all_of(par, pv, nonneg) == all(nonneg(x) for x in xs)
    assert alg.any_of(par, pv, is_even) == any(is_even(x) for x in xs)
    t = alg.transform(par, pv, aff)
    assert isinstance(t, PartitionedVector) and t.dist is pv.dist
    assert t.to_array().tolist() == want_fn
    inc = alg.inclusive_scan(par, pv)
    assert inc.to_array().tolist() == [float(v) for v in np.cumsum(xs)]
    exc = alg.exclusive_scan(par, pv, init=7)
    assert exc.to_array().tolist() == (
        [7.0] + [float(7 + v) for v in np.cumsum(xs)[:-1]] if xs else [])
    if xs:
        assert alg.min_element(par, pv) == float(min(xs))
        assert alg.max_element(par, pv) == float(max(xs))
    alg.sort(par, pv)
    assert pv.to_array().tolist() == [float(v) for v in sorted(xs)]
    filled = alg.fill(par, pv, 9)
    assert filled is pv and set(pv.to_array().tolist()) <= {9.0}


@pytest.mark.parametrize("dname,dist", _dists(6))
def test_segmented_on_empty_and_single_element_segments(net, dname, dist):
    xs = [4.0, -2.0, 7.0, 0.0, 3.0, -8.0]
    pv = mkpv(xs, distribution=dist)
    assert alg.reduce(par, pv) == sum(xs)
    assert alg.min_element(par, pv) == min(xs)
    inc = alg.inclusive_scan(par, pv)
    assert inc.to_array().tolist() == list(np.cumsum(xs))
    alg.sort(par, pv)
    assert pv.to_array().tolist() == sorted(xs)


def test_segmented_empty_vector(net):
    pv = mkpv([])
    assert len(pv) == 0 and pv.to_array().size == 0
    assert alg.reduce(par, pv, init=3) == 3
    assert alg.count_if(par, pv, is_even) == 0
    assert alg.all_of(par, pv, is_even) is True  # vacuous
    assert alg.exclusive_scan(par, pv, init=2).to_array().size == 0
    with pytest.raises(ValueError, match="empty"):
        alg.min_element(par, pv)


def test_segmented_two_way_task_policy(net):
    pv = mkpv(np.arange(12.0))
    f = alg.reduce(par_task, pv)
    assert isinstance(f, Future) and f.get(timeout=60) == 66.0
    f2 = alg.inclusive_scan(par_task, pv)
    assert isinstance(f2, Future)
    assert f2.get(timeout=120).to_array().tolist() == list(
        np.cumsum(np.arange(12.0)))


def test_scan_float_carry_over_int_segments_promotes(net):
    pv = mkpv([1, 2, 3, 4, 5, 6], dtype=np.int64)
    exc = alg.exclusive_scan(par, pv, init=0.5)
    want = [0.5, 1.5, 3.5, 6.5, 10.5, 15.5]
    assert exc.to_array().tolist() == want
    # the handle's dtype must reflect the promotion, or slice() truncates
    assert exc.dtype == np.float64
    assert exc.slice(0, 6).tolist() == want


def test_free_releases_segments_and_name(net):
    from repro.core import agas as _agas

    pv = mkpv(np.arange(6.0))
    name, gid0 = pv.name, pv.segment_gid(0)
    # derived result, freed after use (the transient-result hygiene path)
    t = alg.transform(par, pv, aff)
    t_total = float(alg.reduce(par, t))
    t.free()
    with pytest.raises(rnet.UnknownGid):
        rnet.apply_remote(attach_probe, t.segment_gid(1)).get(timeout=60)
    pv.free()
    assert not _agas.default().contains(gid0)
    assert not _agas.default().contains(name)
    # the name is reusable, and attach() does not serve the stale handle
    pv2 = PartitionedVector.create(name, 3)
    assert len(PartitionedVector.attach(name)) == 3
    assert t_total == sum(aff(x) for x in np.arange(6.0))
    pv2.free()


# --------------------------------------------------- work went to the data
def _wire_bytes(net):
    total = 0.0
    for loc in range(net.n_localities):
        snap = rnet.query_counters(loc, "/net{*}/bytes/sent")
        total += sum(v for _k, v in snap)
    return total


def test_for_each_moves_no_element_bytes(net):
    n = 40_000  # 320 KB of float64 elements
    pv = PartitionedVector.create(f"t/bytes{next(_uid)}", n)
    pv.fill_with(_iota)
    element_bytes = n * 8
    before = _wire_bytes(net)
    alg.for_each(par, pv, touch)
    mid = _wire_bytes(net)
    pv.to_array()
    after = _wire_bytes(net)
    d_foreach = mid - before
    d_fetch_all = after - mid
    assert d_fetch_all > 0.6 * element_bytes, "fetch-all must move the data"
    assert d_foreach < element_bytes * 0.05, \
        f"for_each moved {d_foreach} bytes — work did not go to the data"
    assert d_foreach < d_fetch_all / 10


def _iota(idx):
    return idx.astype(np.float64)


# ----------------------------------------------------- placement / rebalance
def test_move_segment_keeps_gid_and_contents(net):
    xs = np.arange(9.0)
    pv = mkpv(xs)
    gid = pv.segment_gid(0)
    pv.move_segment(0, 2)
    assert pv.owner_of(0) == 2 and pv.segment_gid(0) == gid
    assert np.array_equal(pv.to_array(), xs)


def test_rebalance_preserves_contents_under_concurrent_reads(net):
    xs = np.arange(400.0)
    pv = mkpv(xs)
    stop = threading.Event()
    errors = []

    def reader():
        rng = np.random.default_rng(0)
        while not stop.is_set():
            lo = int(rng.integers(0, 360))
            try:
                got = pv.slice(lo, lo + 32)
                if not np.array_equal(got, xs[lo:lo + 32]):
                    errors.append((lo, got))
            except Exception as e:  # noqa: BLE001
                errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        assert pv.rebalance([1, 2, 0]) == [1, 2, 0]
        assert pv.rebalance([2, 0, 1]) == [2, 0, 1]
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert not errors
    assert pv.owners() == [2, 0, 1]
    assert np.array_equal(pv.to_array(), xs)


# -------------------------------------------------- consumers ride along
def test_sharded_dataset_matches_oracle_and_feeds_locally(net):
    from repro.configs import get_config
    from repro.data.pipeline import (DataConfig, ShardedTokenDataset,
                                     synth_token_rows)

    cfg = get_config("qwen25_3b", smoke=True)
    dcfg = DataConfig(batch_size=4, seq_len=16)
    ds = ShardedTokenDataset.create(f"t/ds{next(_uid)}", cfg, dcfg, rows=30)
    oracle = synth_token_rows(np.arange(30), cfg, dcfg)
    assert np.array_equal(ds.pv.to_array(), oracle)
    feeder = ds.feeder()
    assert feeder.global_rows.shape[0] == 10  # locality 0's block share
    batch = feeder.get(0).get(timeout=60)
    assert batch["tokens"].shape == (4, 17)
    local = {tuple(r) for r in oracle[feeder.global_rows]}
    assert all(tuple(np.asarray(r)) in local for r in batch["tokens"]), \
        "batch rows must come from locally-owned segments"
    # deterministic per step
    again = feeder.get(0).get(timeout=60)
    assert np.array_equal(np.asarray(batch["tokens"]), np.asarray(again["tokens"]))


def test_partitioned_checkpoint_owner_writes_own_shard(net, tmp_path):
    from repro.checkpoint import ckpt

    xs = np.arange(24.0) * 1.5
    pv = mkpv(xs)
    pv.move_segment(0, 1)  # placement at SAVE time must be what restores
    out = ckpt.save_partitioned(tmp_path, step=5, pv=pv)
    import json

    manifest = json.loads((out / "partitioned.json").read_text())
    # each shard was written by the locality owning the segment
    assert [s["locality"] for s in manifest["shards"]] == [1, 1, 2]
    assert (out / "shard_00001.npy").exists()
    step, pv2 = ckpt.restore_partitioned(tmp_path, name=f"t/rst{next(_uid)}")
    assert step == 5
    assert np.array_equal(pv2.to_array(), xs)
    assert pv2.owners() == [1, 1, 2], "save-time placement must survive"
