"""Per-arch smoke (required deliverable): reduced same-family config, one
forward + one train step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.plan import get_plan
from repro.models.model import build_model
from repro.optim import adamw
from repro.train import step as step_mod


def _batch(cfg, rng, B=2, S=32):
    tokens = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(rng)
    batch = _batch(cfg, rng)

    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    step = jax.jit(step_mod.make_train_step(model, adamw.AdamWConfig(lr=1e-3)))
    p2, o2, metrics = step(params, adamw.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for k, v in p2.items():
        assert v.shape == params[k].shape, f"{arch}:{k} shape changed"
        assert np.isfinite(np.asarray(v, np.float32)).all(), f"{arch}:{k} NaN"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_shapes(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(rng)
    B, S = 2, 16
    pin = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        pin["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "encdec":
        pin["enc"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
    logits, cache = jax.jit(model.prefill, static_argnames=("cache_len",))(
        params, pin, cache_len=S + 4)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = jax.jit(model.decode)(params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # padded vocab columns are masked: argmax must stay within real vocab
    assert int(jnp.max(jnp.argmax(logits2, -1))) < cfg.vocab_size


def test_pallas_attention_path_trains(rng):
    """attn_impl='pallas' routes attention through the flash kernel (interpret
    on CPU) and matches the XLA path within bf16 tolerance."""
    from dataclasses import replace

    import repro.models.transformer as T

    cfg = get_config("qwen25_3b", smoke=True)
    plan = get_plan("futurized")
    model = build_model(cfg, plan)
    params = model.init(rng)
    batch = _batch(cfg, rng, B=1, S=128)
    loss_xla = float(jax.jit(model.loss)(params, batch))
    cfg_p = replace(cfg, attn_impl="pallas")
    model_p = build_model(cfg_p, plan)
    loss_pl = float(jax.jit(model_p.loss)(params, batch))
    assert abs(loss_xla - loss_pl) < 0.05
    step = jax.jit(step_mod.make_train_step(model_p, adamw.AdamWConfig(lr=1e-3)))
    _, _, m = step(params, adamw.init(params), batch)
    assert np.isfinite(float(m["loss"]))
