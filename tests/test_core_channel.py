"""Channel (hpx::lcos::channel): ordered streaming, close-on-finish."""
import threading

import pytest

from repro.core.future import Channel, ChannelClosed


def test_channel_fifo_ordering():
    ch = Channel()
    for i in range(5):
        ch.set(i)
    assert [ch.get(timeout=1) for _ in range(5)] == [0, 1, 2, 3, 4]


def test_channel_get_future_before_set():
    ch = Channel()
    f = ch.get_future()
    assert not f.is_ready()
    ch.set(42)
    assert f.get(timeout=1) == 42


def test_channel_close_drains_then_raises():
    ch = Channel()
    ch.set(1)
    ch.set(2)
    ch.close()
    assert ch.get(timeout=1) == 1  # buffered values survive close
    assert ch.get(timeout=1) == 2
    with pytest.raises(ChannelClosed):
        ch.get(timeout=1)
    with pytest.raises(ChannelClosed):
        ch.set(3)


def test_channel_iteration_stops_at_close():
    ch = Channel()
    for i in range(3):
        ch.set(i)
    ch.close()
    assert list(ch) == [0, 1, 2]


def test_channel_close_wakes_blocked_waiters():
    ch = Channel()
    f = ch.get_future()
    ch.close()
    assert f.has_exception()
    with pytest.raises(ChannelClosed):
        f.get(timeout=1)


def test_channel_cross_thread_stream():
    ch = Channel()
    got = []

    def consumer():
        got.extend(ch)

    t = threading.Thread(target=consumer)
    t.start()
    for i in range(20):
        ch.set(i)
    ch.close()
    t.join(timeout=5)
    assert got == list(range(20))
