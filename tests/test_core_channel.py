"""Channel (hpx::lcos::channel): ordered streaming, close-on-finish."""
import threading

import pytest

from repro.core.future import Channel, ChannelClosed


def test_channel_fifo_ordering():
    ch = Channel()
    for i in range(5):
        ch.set(i)
    assert [ch.get(timeout=1) for _ in range(5)] == [0, 1, 2, 3, 4]


def test_channel_get_future_before_set():
    ch = Channel()
    f = ch.get_future()
    assert not f.is_ready()
    ch.set(42)
    assert f.get(timeout=1) == 42


def test_channel_close_drains_then_raises():
    ch = Channel()
    ch.set(1)
    ch.set(2)
    ch.close()
    assert ch.get(timeout=1) == 1  # buffered values survive close
    assert ch.get(timeout=1) == 2
    with pytest.raises(ChannelClosed):
        ch.get(timeout=1)
    with pytest.raises(ChannelClosed):
        ch.set(3)


def test_channel_iteration_stops_at_close():
    ch = Channel()
    for i in range(3):
        ch.set(i)
    ch.close()
    assert list(ch) == [0, 1, 2]


def test_channel_close_wakes_blocked_waiters():
    ch = Channel()
    f = ch.get_future()
    ch.close()
    assert f.has_exception()
    with pytest.raises(ChannelClosed):
        f.get(timeout=1)


def test_channel_close_with_exception_reaches_blocked_readers():
    """close(exc) must deliver the producer's failure to readers already
    blocked in get() — they cannot observe a bare ChannelClosed when the
    stream died of something specific."""
    ch = Channel()
    errs = []

    def consumer():
        try:
            ch.get(timeout=5)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=consumer) for _ in range(3)]
    for t in threads:
        t.start()
    while len(ch._waiters) < 3:  # all three parked before the close
        pass
    boom = RuntimeError("engine fell over")
    ch.close(boom)
    for t in threads:
        t.join(timeout=5)
    assert len(errs) == 3
    assert all(e is boom for e in errs)


def test_channel_close_exception_takes_fifo_position_after_buffer():
    """Tokens produced before the failure drain first, *then* the error —
    a streaming consumer sees everything the producer actually emitted."""
    ch = Channel()
    ch.set("a")
    ch.set("b")
    boom = ValueError("mid-stream death")
    ch.close(boom)
    assert ch.get(timeout=1) == "a"
    assert ch.get(timeout=1) == "b"
    with pytest.raises(ValueError, match="mid-stream death"):
        ch.get(timeout=1)
    # and it keeps raising the same failure, not a bare ChannelClosed
    with pytest.raises(ValueError):
        ch.get_future().get(timeout=1)


def test_channel_second_close_keeps_first_outcome():
    ch = Channel()
    boom = RuntimeError("first")
    ch.close(boom)
    ch.close(ValueError("second"))  # no-op: first outcome wins
    with pytest.raises(RuntimeError, match="first"):
        ch.get(timeout=1)


def test_channel_close_exception_not_swallowed_by_iteration():
    """__iter__ stops only at ChannelClosed; an error close propagates out
    of the for-loop instead of silently ending it."""
    ch = Channel()
    ch.set(1)
    ch.close(RuntimeError("stream broke"))
    got = []
    with pytest.raises(RuntimeError, match="stream broke"):
        for tok in ch:
            got.append(tok)
    assert got == [1]


def test_channel_cross_thread_stream():
    ch = Channel()
    got = []

    def consumer():
        got.extend(ch)

    t = threading.Thread(target=consumer)
    t.start()
    for i in range(20):
        ch.set(i)
    ch.close()
    t.join(timeout=5)
    assert got == list(range(20))
