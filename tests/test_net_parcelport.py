"""Parcelport frame codec: length-prefixed header + pickle5 out-of-band
buffers (the zero-copy fast path). Pure in-process — no sockets."""

import pickle

import numpy as np
import pytest

from repro.net import parcelport as pp


def _round_trip(header, payload=pp._NO_PAYLOAD):
    chunks = pp.encode_frame(header, payload)
    wire = b"".join(bytes(c) for c in chunks)
    total = int.from_bytes(wire[:4], "big")
    assert total == len(wire) - 4
    frame = memoryview(wire[4:])
    hdr, rest = pp.decode_frame(frame)
    return hdr, pp.decode_payload(hdr, rest)


def test_header_only_frame():
    hdr, payload = _round_trip({"t": pp.HELLO, "src": 3, "dst": 0, "seq": 0})
    assert hdr["t"] == pp.HELLO and hdr["src"] == 3
    assert payload is None


def test_payload_with_nested_arrays_out_of_band():
    args = ({"x": np.arange(64, dtype=np.float32),
             "y": [np.ones((4, 4)), "text", 7]},)
    header = {"t": pp.PARCEL, "src": 0, "dst": 1, "seq": 5,
              "a": "mod.fn", "g": [1, 2]}
    hdr, payload = _round_trip(header, (args, {}))
    # arrays really went out of band (zero-copy), not through the pickle
    assert len(hdr["blens"]) >= 2
    assert sum(hdr["blens"]) >= 64 * 4 + 16 * 8
    (got,), kwargs = payload
    np.testing.assert_array_equal(got["x"], args[0]["x"])
    np.testing.assert_array_equal(got["y"][0], np.ones((4, 4)))
    assert got["y"][1:] == ["text", 7]


def test_send_side_chunks_alias_source_buffer():
    """The encoded chunk list carries views of the original array memory —
    nothing was copied into the pickle stream on the send side."""
    arr = np.arange(1024, dtype=np.int64)
    chunks = pp.encode_frame({"t": pp.PARCEL, "src": 0, "dst": 1, "seq": 1,
                              "a": "f", "g": None}, ((arr,), {}))
    views = [c for c in chunks[1:] if isinstance(c, memoryview)]
    assert views, "array buffer should travel out of band"
    base = views[0]
    arr[0] = -1  # mutate the source: the view must observe it (aliasing)
    assert np.frombuffer(base, dtype=np.int64)[0] == -1


def test_exception_payload_round_trips():
    header = {"t": pp.RESULT, "src": 1, "dst": 0, "seq": 9}
    chunks = pp.encode_result_payload(header, None, ValueError("bad"))
    wire = b"".join(bytes(c) for c in chunks)
    hdr, rest = pp.decode_frame(memoryview(wire[4:]))
    exc = pp.decode_payload(hdr, rest)
    assert hdr["ok"] is False
    assert isinstance(exc, ValueError) and exc.args == ("bad",)


def test_unpicklable_result_degrades_to_runtime_error():
    header = {"t": pp.RESULT, "src": 1, "dst": 0, "seq": 9}
    unpicklable = lambda: 0  # noqa: E731 — locals don't pickle
    chunks = pp.encode_result_payload(header, unpicklable, None)
    wire = b"".join(bytes(c) for c in chunks)
    hdr, rest = pp.decode_frame(memoryview(wire[4:]))
    exc = pp.decode_payload(hdr, rest)
    assert hdr["ok"] is False
    assert isinstance(exc, RuntimeError)
    assert "unpicklable" in str(exc)


def test_forward_chunks_preserve_frame():
    header = {"t": pp.PARCEL, "src": 1, "dst": 2, "seq": 3, "a": "f",
              "g": [2, 1]}
    wire = b"".join(bytes(c) for c in pp.encode_frame(header, ((1, 2), {})))
    frame = memoryview(wire[4:])
    fwd = b"".join(bytes(c) for c in pp.forward_chunks(frame))
    assert fwd == wire  # byte-identical re-prefix, payload untouched


def test_namedtuple_payload_survives_host_walk():
    """NamedTuples must be rebuilt field-wise (their __new__ takes
    positional fields, not one iterable) — and only when jax is imported
    does the walk run at all."""
    import collections

    import jax.numpy as jnp

    Point = collections.namedtuple("Point", ["x", "y"])
    globals()["Point"] = Point  # picklable: resolvable from this module
    p = Point(jnp.arange(4, dtype=jnp.float32), "label")
    hdr, payload = _round_trip(
        {"t": pp.PARCEL, "src": 0, "dst": 1, "seq": 1, "a": "f", "g": None},
        (((p,), {})))
    (got,), _ = payload
    assert type(got).__name__ == "Point" and got.y == "label"
    np.testing.assert_array_equal(got.x, np.arange(4, dtype=np.float32))


def test_to_host_is_identity_for_array_free_payloads():
    """The host walk must not rebuild (let alone deep-copy) containers
    holding no ``jax.Array`` leaves: every node comes back ``is`` the
    input, so large numpy/dict/list payloads pay zero walk overhead."""
    import collections

    import jax  # noqa: F401 — the walk only runs once jax is imported

    Rec = collections.namedtuple("Rec", ["a", "b"])
    globals()["Rec"] = Rec
    arr = np.arange(1 << 16, dtype=np.float32)
    leaves = [arr, {"k": [arr, (1, "s")], "m": b"bytes"}, Rec(arr, [2, 3])]
    for obj in leaves:
        assert pp._to_host(obj) is obj
    nested = {"outer": leaves, "t": tuple(leaves)}
    out = pp._to_host(nested)
    assert out is nested
    assert out["outer"] is leaves and out["outer"][0] is arr


def test_to_host_rebuilds_only_branches_holding_device_arrays():
    import jax.numpy as jnp

    arr = np.arange(8, dtype=np.float32)
    clean = {"n": arr, "l": [1, 2]}
    mixed = {"clean": clean, "dev": jnp.arange(4, dtype=jnp.float32)}
    out = pp._to_host(mixed)
    assert out is not mixed                       # device branch rebuilt
    assert out["clean"] is clean                  # untouched branch shared
    assert isinstance(out["dev"], np.ndarray)
    np.testing.assert_array_equal(out["dev"], np.arange(4, dtype=np.float32))


def test_jax_arrays_take_the_host_fast_path():
    import jax.numpy as jnp

    x = jnp.arange(32, dtype=jnp.float32)
    hdr, payload = _round_trip(
        {"t": pp.PARCEL, "src": 0, "dst": 1, "seq": 1, "a": "f", "g": None},
        (((x,), {})))
    assert hdr["blens"], "device array should cross as an OOB host buffer"
    (got,), _ = payload
    assert isinstance(got, np.ndarray)
    np.testing.assert_array_equal(got, np.arange(32, dtype=np.float32))


# --------------------------------------------------- edge-case payloads
def _pheader():
    return {"t": pp.PARCEL, "src": 0, "dst": 1, "seq": 1, "a": "f", "g": None}


@pytest.mark.parametrize("arr", [
    np.array(3.5),                                   # 0-d
    np.array(7, dtype=np.int32),                     # 0-d int
    np.empty((0,), np.float64),                      # empty 1-d
    np.empty((0, 4), np.float32),                    # empty 2-d
    np.arange(100).reshape(10, 10)[:, ::2],          # non-contiguous view
    np.arange(100).reshape(10, 10)[::3],             # strided rows
    np.asfortranarray(np.arange(12.0).reshape(3, 4)),  # F-order
], ids=["0d-f8", "0d-i4", "empty-1d", "empty-2d", "noncontig-cols",
        "strided-rows", "fortran"])
def test_edge_payload_round_trips(arr):
    hdr, payload = _round_trip(_pheader(), (((arr,), {})))
    (got,), _ = payload
    np.testing.assert_array_equal(got, arr)
    assert got.dtype == arr.dtype and got.shape == arr.shape


def test_bf16_round_trips():
    import jax.numpy as jnp

    x = jnp.arange(9, dtype=jnp.bfloat16) / 4
    hdr, payload = _round_trip(_pheader(), (((x,), {})))
    (got,), _ = payload
    assert str(got.dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(x), got)


# ------------------------------------------------- codec property tests
from hypothesis import given, settings, strategies as st  # noqa: E402

_DTYPES = ["<f4", "<f8", "<i4", "<i8", "|u1"]


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 300), st.sampled_from(_DTYPES), st.booleans())
def test_codec_round_trip_property(n, dt, nest):
    arr = (np.arange(n) % 251).astype(np.dtype(dt))
    payload = ({"x": arr, "y": [arr[: n // 2], "tag", 7]} if nest
               else ((arr,), {}))
    hdr, got = _round_trip(_pheader(), payload)
    back = got["x"] if nest else got[0][0]
    np.testing.assert_array_equal(back, arr)
    assert back.dtype == arr.dtype
    if nest:
        np.testing.assert_array_equal(got["y"][0], arr[: n // 2])
        assert got["y"][1:] == ["tag", 7]


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4096), st.sampled_from(_DTYPES))
def test_contiguous_buffers_stay_out_of_band(n, dt):
    """Zero-copy invariant: a C-contiguous array's bytes never enter the
    pickle stream — they travel as out-of-band buffers, and on the send
    side the chunk views alias the source memory."""
    arr = (np.arange(n) % 127).astype(np.dtype(dt))
    chunks = pp.encode_frame(_pheader(), ((arr,), {}))
    views = [c for c in chunks[1:] if isinstance(c, memoryview)]
    assert sum(v.nbytes for v in views) >= arr.nbytes
    # aliasing: mutating the source is visible through the encoded view
    if arr.nbytes:
        first = np.frombuffer(views[0], dtype=arr.dtype)
        arr[0] += 1
        assert first[0] == arr[0]
