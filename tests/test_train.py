"""Training semantics: BSP ≡ futurized math, microbatching ≡ full batch,
loss decreases end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.dist.plan import bsp_plan, futurized_plan, get_plan
from repro.models.model import build_model
from repro.optim import adamw
from repro.train import step as step_mod
from repro.train.trainer import TrainConfig, Trainer


def _setup(plan, arch="qwen25_3b"):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, DataConfig(batch_size=4, seq_len=32), step=0)
    return model, params, batch


def test_bsp_and_futurized_steps_agree():
    """Same math, different collective schedule ⇒ same numbers on 1 device."""
    out = {}
    for plan in (bsp_plan(), futurized_plan()):
        model, params, batch = _setup(plan)
        step = jax.jit(step_mod.make_train_step(model, adamw.AdamWConfig(lr=1e-3)))
        p2, _, m = step(params, adamw.init(params), batch)
        out[plan.name] = (float(m["loss"]), p2)
    assert abs(out["bsp"][0] - out["futurized"][0]) < 1e-5
    for k in out["bsp"][1]:
        np.testing.assert_allclose(np.asarray(out["bsp"][1][k], np.float32),
                                   np.asarray(out["futurized"][1][k], np.float32),
                                   atol=1e-5)


def test_microbatched_grads_match_full_batch():
    model, params, batch = _setup(futurized_plan())
    loss_fn = step_mod.make_loss_fn(model)
    l1, g1 = jax.value_and_grad(loss_fn)(params, batch)
    l2, g2 = step_mod._microbatch_grads(loss_fn, params, batch, 4)
    assert abs(float(l1) - float(l2)) < 1e-3
    # bf16 forward => per-microbatch reduction order differs; grads agree to
    # bf16 accuracy (the fp32 accumulator preserves the sum itself)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k], np.float32),
                                   np.asarray(g2[k], np.float32),
                                   atol=2e-2, rtol=5e-2)


def test_loss_decreases_over_training(rt):
    cfg = get_config("starcoder2_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    tr = Trainer(model, adamw.AdamWConfig(lr=1e-2, warmup_steps=5,
                                          total_steps=40, weight_decay=0.0),
                 DataConfig(batch_size=4, seq_len=48),
                 TrainConfig(steps=40, log_every=10))
    hist = tr.fit()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_grad_clip_bounds_update():
    model, params, batch = _setup(futurized_plan())
    cfg_small = adamw.AdamWConfig(lr=1e-3, grad_clip=1e-9)
    step = jax.jit(step_mod.make_train_step(model, cfg_small))
    p2, _, m = step(params, adamw.init(params), batch)
    # with a tiny clip the parameter change is bounded by ~lr·(1+wd·p)
    delta = max(float(jnp.max(jnp.abs(p2[k] - params[k]))) for k in params)
    assert delta < 1e-2


def test_schedule_warmup_and_decay():
    c = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(adamw.schedule(c, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.schedule(c, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(adamw.schedule(c, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
    assert float(adamw.schedule(c, jnp.asarray(55))) < 1.0


def test_pod_manual_compressed_grads_small_mesh():
    """bf16 pod-axis gradient reduction (partial-manual shard_map) compiles
    and matches the plain path on a tiny host mesh.  (XLA CPU crashes on the
    512-device version — tracked in EXPERIMENTS §Perf; TPU is the target.)"""
    import jax
    import numpy as np
    from repro.dist.collectives import pod_manual_value_and_grad

    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"]) ** 2)

    params = {"w": jnp.ones((4, 4))}
    batch = {"x": jnp.arange(8.0).reshape(2, 4)}
    with jax.set_mesh(mesh):
        f = pod_manual_value_and_grad(loss_fn, mesh, compress=True)
        l1, g1 = jax.jit(f)(params, batch)
    l2, g2 = jax.value_and_grad(loss_fn)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               atol=1e-2)


def test_error_feedback_unbiased_over_steps():
    """Compressed-sum with error feedback converges to the true sum:
    Σ dequant(q_t) + final residual == Σ g_t exactly."""
    import jax
    from repro.dist.collectives import make_error_feedback

    init, compress = make_error_feedback()
    key = jax.random.PRNGKey(0)
    gs = [jax.random.normal(jax.random.fold_in(key, i), (64,)) * 1e-3
          for i in range(50)]
    res = init({"g": gs[0]})
    acc = jnp.zeros((64,), jnp.float32)
    for g in gs:
        q, res = compress({"g": g}, res)
        acc = acc + q["g"].astype(jnp.float32)
    true = sum(g.astype(jnp.float32) for g in gs)
    # with residual folded back in, the compressed stream is exact
    np.testing.assert_allclose(np.asarray(acc + res["g"]), np.asarray(true),
                               atol=1e-6)
    # and without it, the drift stays at bf16 scale (bounded, not growing)
    assert float(jnp.max(jnp.abs(acc - true))) < 1e-4
