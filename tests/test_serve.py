"""Serving engine: continuous batching correctness vs manual greedy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.dist.plan import get_plan
from repro.models.model import build_model
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def served():
    cfg = get_config("starcoder2_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _manual_greedy(model, params, prompt, n):
    pin = {"tokens": jnp.asarray(prompt, jnp.int32)[None, :]}
    logits, cache = jax.jit(model.prefill, static_argnames=("cache_len",))(
        params, pin, cache_len=96)
    out = [int(jnp.argmax(logits, -1)[0])]
    dec = jax.jit(model.decode)
    for _ in range(n):
        logits, cache = dec(params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def test_engine_matches_manual_greedy(rt, served):
    cfg, model, params = served
    prompts = [[5, 6, 7, 8], [100, 3, 50, 2, 9, 11], [42]]
    n = 6
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=n))
    futs = [eng.submit(p) for p in prompts]
    outs = [f.get(timeout=300) for f in futs]
    for p, o in zip(prompts, outs):
        assert o == _manual_greedy(model, params, p, n), f"prompt {p}"


def test_engine_more_requests_than_slots(rt, served):
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=64,
                                            max_new_tokens=3))
    futs = [eng.submit([i + 1, i + 2]) for i in range(7)]
    outs = [f.get(timeout=300) for f in futs]
    assert all(len(o) == 4 for o in outs)


def test_engine_counters(rt, served):
    from repro.core import counters

    cfg, model, params = served
    before = counters.get_value("/serve{engine#0}/requests/completed")
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=64,
                                            max_new_tokens=2))
    eng.submit([1, 2, 3]).get(timeout=300)
    assert counters.get_value("/serve{engine#0}/requests/completed") == before + 1


def test_engine_with_serve_plan(rt, served):
    """The production `serve` plan (TP-only + seq-sharded KV) produces the
    same greedy tokens as the futurized plan on one device."""
    from repro.dist.plan import get_plan

    cfg, model, params = served
    model2 = build_model(cfg, get_plan("serve"))
    eng1 = Engine(model, params, ServeConfig(max_batch=2, cache_len=64,
                                             max_new_tokens=4))
    eng2 = Engine(model2, params, ServeConfig(max_batch=2, cache_len=64,
                                              max_new_tokens=4))
    p = [9, 8, 7, 6]
    assert eng1.submit(p).get(timeout=300) == eng2.submit(p).get(timeout=300)
