"""Serving-path correctness: prefill + decode must reproduce the full
forward pass next-token logits (per arch).  MoE archs run with a large
capacity factor — capacity drops are the one legitimate divergence
(asserted separately in test_moe.py)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.plan import get_plan
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.model import build_model

PLAN = get_plan("futurized")


def _forward(cfg, params, tokens, pin):
    if cfg.family == "encdec":
        return encdec.forward(cfg, PLAN, params, pin["enc"], tokens)[0]
    if cfg.family == "ssm":
        return ssm_lm.forward(cfg, PLAN, params, tokens)[0]
    if cfg.family == "hybrid":
        return hybrid.forward(cfg, PLAN, params, tokens)[0]
    return transformer.forward(cfg, PLAN, params, tokens,
                               patches=pin.get("patches"))[0]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    if cfg.is_moe:
        cfg = replace(cfg, capacity_factor=64.0)  # no drops → exact
    model = build_model(cfg, PLAN)
    params = model.init(rng)
    B, S = 2, 32
    tokens = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    pin = {"tokens": tokens[:, :S]}
    if cfg.family == "vlm":
        pin["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "encdec":
        pin["enc"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)

    logits_p, cache = jax.jit(model.prefill, static_argnames=("cache_len",))(
        params, pin, cache_len=S + 8)
    err_p = float(jnp.max(jnp.abs(logits_p - _forward(cfg, params, tokens[:, :S], pin)[:, -1])))
    assert err_p < 0.05, f"{arch} prefill mismatch {err_p}"

    logits_d, _ = jax.jit(model.decode)(params, cache, tokens[:, S:S + 1])
    err_d = float(jnp.max(jnp.abs(logits_d - _forward(cfg, params, tokens, pin)[:, -1])))
    assert err_d < 0.05, f"{arch} decode mismatch {err_d}"


def test_multi_step_decode_matches_forward(rng):
    """Decode 4 tokens autoregressively == forward over the grown sequence."""
    cfg = get_config("qwen25_3b", smoke=True)
    model = build_model(cfg, PLAN)
    params = model.init(rng)
    B, S, N = 2, 16, 4
    tokens = jax.random.randint(rng, (B, S + N), 0, cfg.vocab_size)
    pin = {"tokens": tokens[:, :S]}
    _, cache = jax.jit(model.prefill, static_argnames=("cache_len",))(
        params, pin, cache_len=S + N + 2)
    dec = jax.jit(model.decode)
    for t in range(N):
        logits, cache = dec(params, cache, tokens[:, S + t:S + t + 1])
        full = _forward(cfg, params, tokens[:, :S + t + 1], pin)[:, -1]
        err = float(jnp.max(jnp.abs(logits - full)))
        assert err < 0.05, f"step {t}: {err}"


def test_windowed_decode_ring_buffer(rng):
    """Hybrid arch: decoding past the window wraps the ring buffer and still
    matches the full forward (which sees the same effective window)."""
    cfg = get_config("recurrentgemma_2b", smoke=True)  # window = 32
    model = build_model(cfg, PLAN)
    params = model.init(rng)
    B, S, N = 1, 32, 6  # prefill exactly one window, then wrap
    tokens = jax.random.randint(rng, (B, S + N), 0, cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": tokens[:, :S]})
    dec = jax.jit(model.decode)
    for t in range(N):
        logits, cache = dec(params, cache, tokens[:, S + t:S + t + 1])
        full = _forward(cfg, params, tokens[:, :S + t + 1], {})[:, -1]
        err = float(jnp.max(jnp.abs(logits - full)))
        assert err < 0.05, f"wrap step {t}: {err}"
