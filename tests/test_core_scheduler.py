"""Work-stealing scheduler & policies (HPX P2, paper §2.1)."""
import threading
import time

import pytest

import repro.core as core
from repro.core.scheduler import PRIORITY_HIGH, Runtime


@pytest.mark.parametrize("policy", ["static", "local", "hierarchical"])
def test_policies_run_all_tasks(policy):
    with Runtime(num_workers=3, policy=policy) as rt:
        futs = [rt.spawn(lambda i=i: i * i) for i in range(50)]
        assert sorted(f.get() for f in futs) == [i * i for i in range(50)]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        Runtime(num_workers=1, policy="mystery")


def test_stealing_happens_under_local_policy():
    with Runtime(num_workers=4, policy="local", pool_name="steal-test") as rt:
        # one worker gets all tasks via hint; others must steal
        futs = [rt.spawn(lambda: time.sleep(0.002), worker_hint=0)
                for _ in range(64)]
        for f in futs:
            f.get()
        from repro.core import counters

        assert counters.get_value("/scheduler{steal-test}/tasks/stolen") > 0


def test_static_policy_never_steals():
    with Runtime(num_workers=4, policy="static", pool_name="static-test") as rt:
        futs = [rt.spawn(lambda i=i: i, worker_hint=i % 4) for i in range(40)]
        for f in futs:
            f.get()
        from repro.core import counters

        assert counters.get_value("/scheduler{static-test}/tasks/stolen") == 0


def test_high_priority_runs(rt):
    f = rt.spawn(lambda: "hi", priority=PRIORITY_HIGH)
    assert f.get() == "hi"


def test_counters_track_execution():
    with Runtime(num_workers=2, pool_name="count-test") as rt:
        for f in [rt.spawn(lambda: None) for _ in range(10)]:
            f.get()
        from repro.core import counters

        assert counters.get_value("/scheduler{count-test}/tasks/executed") >= 10
        assert counters.get_value("/scheduler{count-test}/tasks/spawned") >= 10


def test_oversubscription_many_tasks(rt):
    futs = [core.spawn(lambda i=i: i) for i in range(2000)]
    assert sum(f.get() for f in futs) == sum(range(2000))
