"""Work-stealing scheduler & policies (HPX P2, paper §2.1)."""
import threading
import time

import pytest

import repro.core as core
from repro.core.scheduler import PRIORITY_HIGH, Runtime


@pytest.mark.parametrize("policy", ["static", "local", "hierarchical"])
def test_policies_run_all_tasks(policy):
    with Runtime(num_workers=3, policy=policy) as rt:
        futs = [rt.spawn(lambda i=i: i * i) for i in range(50)]
        assert sorted(f.get() for f in futs) == [i * i for i in range(50)]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        Runtime(num_workers=1, policy="mystery")


def test_stealing_happens_under_local_policy():
    with Runtime(num_workers=4, policy="local", pool_name="steal-test") as rt:
        # one worker gets all tasks via hint; others must steal
        futs = [rt.spawn(lambda: time.sleep(0.002), worker_hint=0)
                for _ in range(64)]
        for f in futs:
            f.get()
        from repro.core import counters

        assert counters.get_value("/scheduler{steal-test}/tasks/stolen") > 0


def test_static_policy_never_steals():
    with Runtime(num_workers=4, policy="static", pool_name="static-test") as rt:
        futs = [rt.spawn(lambda i=i: i, worker_hint=i % 4) for i in range(40)]
        for f in futs:
            f.get()
        from repro.core import counters

        assert counters.get_value("/scheduler{static-test}/tasks/stolen") == 0


def test_high_priority_runs(rt):
    f = rt.spawn(lambda: "hi", priority=PRIORITY_HIGH)
    assert f.get() == "hi"


def test_counters_track_execution():
    with Runtime(num_workers=2, pool_name="count-test") as rt:
        for f in [rt.spawn(lambda: None) for _ in range(10)]:
            f.get()
        from repro.core import counters

        assert counters.get_value("/scheduler{count-test}/tasks/executed") >= 10
        assert counters.get_value("/scheduler{count-test}/tasks/spawned") >= 10


def test_oversubscription_many_tasks(rt):
    futs = [core.spawn(lambda i=i: i) for i in range(2000)]
    assert sum(f.get() for f in futs) == sum(range(2000))


# ------------------------- utilization accounting (fleet health observatory)
def test_accounting_busy_idle_clocks_accumulate():
    with Runtime(num_workers=2, policy="local", pool_name="acct-test") as rt:
        for f in [rt.spawn(lambda: time.sleep(0.005)) for _ in range(20)]:
            f.get()
        from repro.core import counters

        busy = counters.get_value("/scheduler{acct-test}/time/busy")
        idle = counters.get_value("/scheduler{acct-test}/time/idle")
        util = counters.get_value("/scheduler{acct-test}/utilization")
        idle_rate = counters.get_value("/scheduler{acct-test}/idle-rate")
        assert busy > 0.0 and idle >= 0.0
        assert 0.0 < util <= 1.0
        assert 0.0 <= idle_rate < 1.0
        # the two gauges are lock-free reads taken moments apart, so allow
        # the live-interval drift — they must still be near-complementary
        assert abs((util + idle_rate) - 1.0) < 0.1
        pool = rt.pool("acct-test")
        b, i = pool.time_totals()
        snap = pool.utilization_snapshot()
        assert len(snap["busy"]) == 2 and len(snap["idle"]) == 2
        assert abs(sum(snap["busy"]) - b) < 0.1


def test_steal_matrix_attributes_victim_and_thief():
    with Runtime(num_workers=3, policy="local",
                 pool_name="acct-steal") as rt:
        # all work lands on worker 0; the other two must steal from it
        futs = [rt.spawn(lambda: time.sleep(0.002), worker_hint=0)
                for _ in range(64)]
        for f in futs:
            f.get()
        pool = rt.pool("acct-steal")
        m = pool.steal_matrix()
        assert sum(m.values()) > 0
        assert sum(n for (v, _t), n in m.items() if v == 0) > 0
        from repro.core import counters

        published = sum(
            counters.get_value(
                f"/scheduler{{acct-steal}}/steals/victim#{v}/thief#{t}")
            for v in range(3) for t in range(3) if v != t)
        assert published == sum(m.values())


def test_queue_depth_gauges_registered():
    with Runtime(num_workers=2, policy="local", pool_name="qd-test") as rt:
        from repro.core import counters

        reg = counters.default()
        assert reg.get("/scheduler{qd-test}/queue/worker#0/depth") is not None
        assert reg.get("/scheduler{qd-test}/queue/worker#1/depth") is not None
        assert counters.get_value("/scheduler{qd-test}/queue/high/depth") >= 0
        for f in [rt.spawn(lambda: None) for _ in range(10)]:
            f.get()


def test_accounting_opt_out_registers_nothing():
    with Runtime(num_workers=2, pool_name="noacct-test",
                 accounting=False) as rt:
        for f in [rt.spawn(lambda: None) for _ in range(10)]:
            f.get()
        from repro.core import counters

        reg = counters.default()
        assert reg.get("/scheduler{noacct-test}/idle-rate") is None
        assert reg.get("/scheduler{noacct-test}/time/busy") is None
        # the execution counters are unconditional — only accounting is off
        assert counters.get_value("/scheduler{noacct-test}/tasks/executed") >= 10
