"""Task-based pipeline parallelism: dataflow 1F1B == monolithic training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core.future import wait_all
from repro.train.pipeline import pipeline_value_and_grad, split_stages


def _stage(params, x):
    w1, w2 = params
    return jnp.tanh(x @ w1) @ w2


def _loss(y, target):
    return jnp.mean((y - target) ** 2)


@pytest.fixture()
def problem(rng):
    ks = jax.random.split(rng, 9)
    D = 16
    stage_params = [
        (jax.random.normal(ks[2 * s], (D, D)) * 0.3,
         jax.random.normal(ks[2 * s + 1], (D, D)) * 0.3)
        for s in range(4)
    ]
    xs = jax.random.normal(ks[8], (8, D))
    tgt = jnp.ones((8, D)) * 0.1
    return stage_params, xs, tgt


def _monolithic(stage_params, xs, tgt):
    def full(params, x):
        for p in params:
            x = _stage(p, x)
        return _loss(x, tgt)

    return jax.value_and_grad(full)(stage_params, xs)


def test_pipeline_matches_monolithic(rt, problem):
    stage_params, xs, tgt = problem
    # 4 microbatches of 2
    mbs = [(xs[i:i + 2], tgt[i:i + 2]) for i in range(0, 8, 2)]
    fns = [_stage] * 4
    loss_f, grad_fs = pipeline_value_and_grad(fns, _loss, stage_params, mbs)
    loss_ref, grads_ref = _monolithic(stage_params, xs, tgt)
    assert abs(float(loss_f.get(timeout=120)) - float(loss_ref)) < 1e-5
    for s, gf in enumerate(grad_fs):
        got = gf.get(timeout=120)
        for a, b in zip(got, grads_ref[s]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-4)


def test_pipeline_task_count(rt, problem):
    """S·M forward + S·M backward + M loss tasks execute (the dataflow tree)."""
    from repro.core import counters

    stage_params, xs, tgt = problem
    before = counters.get_value("/pipeline{1f1b}/tasks/cumulative")
    mbs = [(xs[i:i + 2], tgt[i:i + 2]) for i in range(0, 8, 2)]
    loss_f, grad_fs = pipeline_value_and_grad([_stage] * 4, _loss,
                                              stage_params, mbs)
    wait_all([loss_f, *grad_fs])
    ran = counters.get_value("/pipeline{1f1b}/tasks/cumulative") - before
    assert ran == 4 * 4 + 4 * 4 + 4  # fwd + bwd + loss


def test_split_stages_partition():
    layers = list(range(10))
    st = split_stages(layers, 4)
    assert [len(s) for s in st] == [3, 3, 2, 2]
    assert sum(st, []) == layers
