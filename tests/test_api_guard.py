"""Lint-style guards over the sanctioned subsystem boundaries.

1. Executors are the only entry to the scheduler pools: no module outside
   ``repro/core`` may reach ``scheduler.spawn``/``spawn_raw`` (or any
   ``.spawn(`` call) directly — consumers go through the executor
   hierarchy (``Runtime.get_executor`` / ``repro.core.executor``), which
   is what makes pool placement (io/prefill/default) auditable.
2. ``repro/net`` is the only transport: no module outside it may open
   sockets or fork/spawn OS processes.  Everything that crosses a process
   boundary must be a parcel on the parcelport — one wire format, one set
   of counters, one shutdown path.  (Exemption: ``launch/dryrun.py``
   subprocesses *itself* per compile cell for memory isolation; that is a
   compiler-driver concern, not a transport.)
"""

import re
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"

# scheduler entry points that bypass the executor surface
_BANNED = re.compile(
    r"(spawn_raw"                 # fire-and-forget scheduler internal
    r"|scheduler\.spawn"          # module-level hpx::async
    r"|_sched\.spawn"
    r"|\bspawn\s*\("              # rt.spawn(...) / spawn(...)
    r"|\.spawn\s*\()"
)

# model/optimizer initializers named *.init are fine; these are the
# scheduler's own modules where the substrate lives
_ALLOWED_DIRS = {SRC / "core"}

# transport primitives: sockets and process creation
_NET_BANNED = re.compile(
    r"(\bimport\s+socket\b|\bfrom\s+socket\s+import"
    r"|\bimport\s+socketserver\b|\bfrom\s+socketserver\s+import"
    r"|\bimport\s+http\.server\b|\bfrom\s+http\.server\s+import"
    r"|\bimport\s+multiprocessing\b|\bfrom\s+multiprocessing\s+import"
    r"|\bos\.fork\b|\bpty\.fork\b"
    r"|\bimport\s+subprocess\b|\bfrom\s+subprocess\s+import)"
)
_NET_ALLOWED_DIRS = {SRC / "net"}
_NET_ALLOWED_FILES = {SRC / "launch" / "dryrun.py"}  # compile-cell isolation


def test_no_scheduler_spawn_outside_core():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if any(parent in _ALLOWED_DIRS for parent in path.parents):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _BANNED.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "scheduler.spawn/spawn_raw used outside repro/core — route through "
        "executors (Runtime.get_executor / repro.core.executor):\n"
        + "\n".join(offenders))


def test_guard_matches_known_spellings():
    for bad in ("rt.spawn(fn)", "scheduler.spawn(fn)", "_sched.spawn_raw(f)",
                "pool.spawn_raw(cb)", "spawn (fn)"):
        assert _BANNED.search(bad), bad
    for ok in ("model.init(key)", "prespawned", "respawn_counter = 1",
               "executor.async_execute(fn)", "_spawn_engine(rt, arch)",
               'ctx = mp.get_context("spawn")'):
        assert not _BANNED.search(ok), ok


def test_no_sockets_or_process_creation_outside_net():
    """Only repro/net talks to the OS about wires and processes."""
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if any(parent in _NET_ALLOWED_DIRS for parent in path.parents):
            continue
        if path in _NET_ALLOWED_FILES:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _NET_BANNED.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "sockets / process creation outside repro/net — route cross-process "
        "work through the parcelport (repro.net):\n" + "\n".join(offenders))


def test_net_guard_matches_known_spellings():
    for bad in ("import socket", "from socket import socketpair",
                "import multiprocessing as mp", "os.fork()",
                "import subprocess", "from subprocess import run",
                "from http.server import ThreadingHTTPServer",
                "import socketserver"):
        assert _NET_BANNED.search(bad), bad
    for ok in ("websocket_url = 1", "# talks over a socket", "forked = True",
               "import socketserver_shim"):
        assert not _NET_BANNED.search(ok), ok
