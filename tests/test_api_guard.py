"""Lint-style guard: executors are the only sanctioned entry to the pools.

No module outside ``repro/core`` may reach ``scheduler.spawn``/``spawn_raw``
(or any ``.spawn(`` call) directly — consumers go through the executor
hierarchy (``Runtime.get_executor`` / ``repro.core.executor``), which is
what makes pool placement (io/prefill/default) auditable and testable.
"""

import re
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"

# scheduler entry points that bypass the executor surface
_BANNED = re.compile(
    r"(spawn_raw"                 # fire-and-forget scheduler internal
    r"|scheduler\.spawn"          # module-level hpx::async
    r"|_sched\.spawn"
    r"|\bspawn\s*\("              # rt.spawn(...) / spawn(...)
    r"|\.spawn\s*\()"
)

# model/optimizer initializers named *.init are fine; these are the
# scheduler's own modules where the substrate lives
_ALLOWED_DIRS = {SRC / "core"}


def test_no_scheduler_spawn_outside_core():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if any(parent in _ALLOWED_DIRS for parent in path.parents):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _BANNED.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "scheduler.spawn/spawn_raw used outside repro/core — route through "
        "executors (Runtime.get_executor / repro.core.executor):\n"
        + "\n".join(offenders))


def test_guard_matches_known_spellings():
    for bad in ("rt.spawn(fn)", "scheduler.spawn(fn)", "_sched.spawn_raw(f)",
                "pool.spawn_raw(cb)", "spawn (fn)"):
        assert _BANNED.search(bad), bad
    for ok in ("model.init(key)", "prespawned", "respawn_counter = 1",
               "executor.async_execute(fn)"):
        assert not _BANNED.search(ok), ok
