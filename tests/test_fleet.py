"""Fleet control plane, no network: Policy threshold/sustain/cooldown
semantics, AdmissionController hysteresis, FleetController tick wiring
(measure → decide → act → release) against stub engines."""

import pytest

from repro.fleet import AdmissionController, FleetController, Policy
from repro.fleet.policy import EngineView, FleetView


def _view(occ=0.0, now=0.0, gated=0, engines=()):
    return FleetView(now=now, engines=list(engines), occupancy=occ,
                     gated_depth=gated)


# ------------------------------------------------------------------- Policy
def test_policy_fires_up_after_sustain_ticks():
    p = Policy("scale", metric=lambda v: v.occupancy,
               high=0.8, up="grow", sustain=3, cooldown=0.0)
    assert p.evaluate(_view(0.9), now=1.0) is None
    assert p.evaluate(_view(0.9), now=2.0) is None
    assert p.evaluate(_view(0.9), now=3.0) == "grow"


def test_policy_streak_resets_on_dip():
    p = Policy("scale", metric=lambda v: v.occupancy,
               high=0.8, up="grow", sustain=2, cooldown=0.0)
    assert p.evaluate(_view(0.9), now=1.0) is None
    assert p.evaluate(_view(0.5), now=2.0) is None  # dip resets the streak
    assert p.evaluate(_view(0.9), now=3.0) is None
    assert p.evaluate(_view(0.9), now=4.0) == "grow"


def test_policy_cooldown_silences_refire():
    p = Policy("scale", metric=lambda v: v.occupancy,
               high=0.8, up="grow", sustain=1, cooldown=10.0)
    assert p.evaluate(_view(0.9), now=0.0) == "grow"
    assert p.evaluate(_view(0.9), now=5.0) is None   # inside cooldown
    assert p.evaluate(_view(0.9), now=11.0) == "grow"


def test_policy_two_sided():
    p = Policy("elastic", metric=lambda v: v.total_load(),
               high=8.0, up="grow", low=1.0, down="shrink",
               sustain=1, cooldown=0.0)
    heavy = _view(engines=[EngineView("e", 1, None, 9.0, 0.5)])
    idle = _view(engines=[EngineView("e", 1, None, 0.0, 0.1)])
    assert p.evaluate(heavy, now=0.0) == "grow"
    assert p.evaluate(idle, now=1.0) == "shrink"
    assert p.evaluate(_view(engines=[EngineView("e", 1, None, 4.0, 0.3)]),
                      now=2.0) is None


def test_policy_one_sided_requires_pairing():
    import pytest

    with pytest.raises(AssertionError):
        Policy("bad", metric=lambda v: 0.0, high=1.0)  # high without up


# -------------------------------------------------------------- Admission
def test_admission_hysteresis_edges():
    sig = {"occ": 0.0}
    gate = AdmissionController(lambda: sig["occ"], high=0.85, low=0.60)
    assert gate.allow()
    sig["occ"] = 0.86
    assert not gate.allow()       # closed at high
    sig["occ"] = 0.70
    assert not gate.allow()       # still closed between low and high
    sig["occ"] = 0.59
    assert gate.allow()           # reopened at low
    sig["occ"] = 0.84
    assert gate.allow()           # stays open below high


def test_admission_fails_open_without_signal():
    def broken():
        raise RuntimeError("no gossip yet")

    gate = AdmissionController(broken, high=0.85, low=0.60)
    assert gate.allow()


# ---------------------------------------------------------- FleetController
class _StubEngine:
    def __init__(self, name, load=0.0, occ=0.0):
        self.name = name
        self._load = load
        self._occ = occ

    def load(self):
        return self._load

    def occupancy(self):
        return self._occ


class _StubRouter:
    def __init__(self, engines):
        self.engines = engines
        self.released = 0

    def tier_of(self, name):
        return None

    def gated_depth(self):
        return 0

    def release_gated(self, limit=None):
        self.released += 1
        return 0


class _StubNet:
    locality = 0

    def live_ids(self):
        return [0]


def _local_sampler():
    from repro.obs.sampler import FleetSampler

    return FleetSampler(pattern="/serve*", interval=0.01)  # net=None: local


def test_controller_tick_measures_decides_acts(rt):
    router = _StubRouter([_StubEngine("a", load=2.0, occ=0.9),
                          _StubEngine("b", load=1.0, occ=0.4)])
    fired = []
    ctl = FleetController(_StubNet(), router, interval=0.01,
                          sampler=_local_sampler())
    ctl.add_policy(Policy("scale", metric=lambda v: v.occupancy,
                          high=0.8, up="grow", sustain=1, cooldown=0.0))
    ctl.register("grow", lambda view: fired.append(view.occupancy))
    view = ctl.tick()
    assert view.occupancy == 0.9          # max across engines
    assert view.total_load() == 3.0
    assert fired == [0.9]                 # actuator ran with the view
    assert router.released == 1           # release sweep every tick


def test_controller_actuator_failure_is_contained(rt):
    router = _StubRouter([_StubEngine("a", occ=1.0)])
    ctl = FleetController(_StubNet(), router, interval=0.01,
                          sampler=_local_sampler())
    ctl.add_policy(Policy("scale", metric=lambda v: v.occupancy,
                          high=0.5, up="grow", sustain=1, cooldown=0.0))

    def boom(view):
        raise RuntimeError("spawn failed")

    ctl.register("grow", boom)
    before = ctl.c_action_errors.get_value()
    ctl.tick()                            # must not raise
    assert ctl.c_action_errors.get_value() == before + 1


def test_controller_unknown_actuator_counts_error(rt):
    router = _StubRouter([_StubEngine("a", occ=1.0)])
    ctl = FleetController(_StubNet(), router, interval=0.01,
                          sampler=_local_sampler())
    ctl.add_policy(Policy("scale", metric=lambda v: v.occupancy,
                          high=0.5, up="nonexistent", sustain=1,
                          cooldown=0.0))
    before = ctl.c_action_errors.get_value()
    ctl.tick()
    assert ctl.c_action_errors.get_value() == before + 1


def test_view_pool_utilization_from_busy_idle_rates():
    from repro.fleet import utilization_policy

    rates = {
        (0, "/scheduler{default}/time/busy"): 0.9,
        (0, "/scheduler{default}/time/idle"): 0.1,
        (1, "/scheduler{default}/time/busy"): 0.7,
        (1, "/scheduler{default}/time/idle"): 0.3,
    }
    view = FleetView(now=0.0, rates=rates)
    assert view.pool_utilization(0) == pytest.approx(0.9)
    assert view.pool_idle_rate(0) == pytest.approx(0.1)
    assert view.mean_utilization() == pytest.approx(0.8)
    # never-sampled locality reads idle, not saturated
    assert view.pool_utilization(7) == 0.0
    assert view.pool_idle_rate(7) == 1.0

    pol = utilization_policy(high=0.75, low=0.1, sustain=2, cooldown=0.0)
    assert pol.evaluate(view, now=0.0) is None      # streak 1
    assert pol.evaluate(view, now=1.0) == "grow"    # sustained saturation
