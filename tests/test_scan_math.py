"""Property tests: chunked/associative scan formulations == sequential
oracles (the system's core numerical invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models.rglru import rglru_scan
from repro.models.ssm import ssd_chunked, ssd_decode_step


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 2),
       st.integers(0, 2**31 - 1))
def test_ssd_chunked_matches_sequential(B, nq, G, seed):
    S = nq * 16
    H, P, N = 2 * G, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_ref, h_ref = ref.ssd(x, dt, A, Bm, Cm)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ssd_decode_continues_prefill_state(seed):
    """prefill state + one recurrent step == sequential over S+1."""
    B, S, H, P, G, N = 1, 32, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S + 1, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S + 1, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S + 1, G, N)) * 0.3
    _, h_prefill = ssd_chunked(x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S], chunk=16)
    y1, h1 = ssd_decode_step(h_prefill, x[:, S], dt[:, S], A, Bm[:, S], Cm[:, S])
    y_ref, h_ref = ref.ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_ref[:, S]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h_ref), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(3, 65), st.integers(0, 2**31 - 1))
def test_rglru_assoc_scan_matches_sequential(B, S, seed):
    W = 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W)) * 0.2
    h = rglru_scan(a.astype(jnp.float32), b.astype(jnp.float32))
    e = ref.rglru(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(e), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rglru_h0_fold(seed):
    """Scan with initial state == sequential continuation."""
    B, S, W = 1, 20, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, 2 * S, W)))
    b = jax.random.normal(ks[1], (B, 2 * S, W)) * 0.2
    full = ref.rglru(a, b)
    h_mid = full[:, S - 1].astype(jnp.float32)
    second = rglru_scan(a[:, S:].astype(jnp.float32),
                        b[:, S:].astype(jnp.float32), h0=h_mid)
    np.testing.assert_allclose(np.asarray(second), np.asarray(full[:, S:]),
                               atol=1e-5)
