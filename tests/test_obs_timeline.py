"""Persisted counter timelines + the fleet-top dashboard (ISSUE 10)."""

import json

import pytest

from repro.obs import timeseries as TS


def _sweep(busy, idle, extra=None):
    pairs = [("/scheduler{default}/time/busy", busy),
             ("/scheduler{default}/time/idle", idle),
             ("/scheduler{default}/idle-rate", idle / (busy + idle)),
             ("/scheduler{default}/utilization", busy / (busy + idle))]
    if extra:
        pairs += extra
    return {0: pairs}


# ------------------------------------------------------------------ writer
def test_writer_round_trip(tmp_path):
    path = str(tmp_path / "tl.jsonl")
    with TS.TimelineWriter(path, pattern="/scheduler*") as w:
        w.append(_sweep(1.0, 1.0), now=1.0)
        w.append(_sweep(2.0, 1.5), now=2.0)
    header, records = TS.read_timeline(path)
    assert header["pattern"] == "/scheduler*"
    assert header["version"] == TS.VERSION
    assert len(records) == 2
    pts = TS.series(records, 0, "/scheduler{default}/time/busy")
    assert pts == [(1.0, 1.0), (2.0, 2.0)]


def test_writer_records_dead_peer_markers(tmp_path):
    path = str(tmp_path / "tl.jsonl")
    with TS.TimelineWriter(path) as w:
        sweep = dict(_sweep(1.0, 1.0))
        sweep[3] = {"error": "ConnectionError('gone')"}
        w.append(sweep, now=1.0)
    _h, records = TS.read_timeline(path)
    assert records[0]["errors"] == [3]


def test_stride_doubling_bounds_the_file(tmp_path):
    path = str(tmp_path / "tl.jsonl")
    w = TS.TimelineWriter(path, max_records=8)
    for i in range(100):
        w.append(_sweep(float(i + 1), float(i + 1)), now=float(i))
    w.close()
    _h, records = TS.read_timeline(path)
    assert len(records) <= 8
    assert w.stride > 1 and w.compactions >= 1
    # newest data survives every compaction
    assert records[-1]["t"] >= 96.0
    # strides recorded per record, monotone non-decreasing
    strides = [r["stride"] for r in records]
    assert strides == sorted(strides)


def test_append_after_close_raises(tmp_path):
    w = TS.TimelineWriter(str(tmp_path / "tl.jsonl"))
    w.close()
    with pytest.raises(ValueError):
        w.append(_sweep(1.0, 1.0))


def test_read_rejects_non_timeline(tmp_path):
    p = tmp_path / "not_tl.jsonl"
    p.write_text('{"foo": 1}\n')
    with pytest.raises(ValueError):
        TS.read_timeline(str(p))


# --------------------------------------------------------------- summarize
def test_summarize_derives_utilization(tmp_path):
    path = str(tmp_path / "tl.jsonl")
    with TS.TimelineWriter(path) as w:
        busy = idle = 0.0
        for i in range(10):
            busy += 0.7
            idle += 0.3
            w.append(_sweep(busy, idle), now=float(i))
    s = TS.summarize(path)
    assert s["records"] == 10
    util = s["utilization"][(0, "default")]
    assert util["utilization"] == pytest.approx(0.7, abs=1e-9)
    assert util["idle_rate"] == pytest.approx(0.3, abs=1e-9)
    st = s["counters"][(0, "/scheduler{default}/time/busy")]
    assert st["rate"] == pytest.approx(0.7, abs=1e-9)
    lines = TS.format_summary(s)
    assert any("utilization" in ln for ln in lines)


def test_analyze_timeline_cli(tmp_path, capsys):
    from repro.obs import analyze

    path = str(tmp_path / "tl.jsonl")
    with TS.TimelineWriter(path) as w:
        for i in range(5):
            w.append(_sweep(0.6 * (i + 1), 0.4 * (i + 1)), now=float(i))
    assert analyze.main(["--timeline", path]) == 0
    out = capsys.readouterr().out
    assert "timeline:" in out and "scheduler{default}" in out

    assert analyze.main(["--timeline", path, "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["utilization"]["L0 default"]["utilization"] == \
        pytest.approx(0.6, abs=1e-9)

    assert analyze.main(["--timeline", str(tmp_path / "missing.jsonl")]) == 1


# -------------------------------------------------------------- fleet-top
def test_top_snapshot_and_frame_from_sampler(rt):
    import repro.core as core
    from repro.obs import top as T
    from repro.obs.sampler import FleetSampler

    ex = rt.get_executor("default")
    for f in [ex.async_execute(lambda: sum(range(5000))) for _ in range(30)]:
        f.get()
    sampler = FleetSampler(pattern="*", net=None)
    sampler.sample_once()
    snap = T.snapshot_from_sampler(sampler)
    assert any(pool == "default" for (_loc, pool) in snap["pools"])
    frame = T.render_frame(snap)
    assert "fleet-top" in frame and "scheduler{default}" in frame
    assert core.counters.get_value("/scheduler{default}/time/busy") > 0


def test_top_snapshot_from_metrics_round_trip():
    from repro.core import counters as C
    from repro.obs import metrics as M
    from repro.obs import top as T

    reg = C.CounterRegistry()
    reg.register_callable("/scheduler{default}/utilization", lambda: 0.8)
    reg.register_callable("/scheduler{default}/idle-rate", lambda: 0.2)
    reg.register_callable("/scheduler{default}/queue/worker#0/depth",
                          lambda: 3.0)
    reg.gauge("/serve{engine#1}/request/latency/p99").set(0.125)
    reg.gauge("/net{locality#0/peer#1}/credit/inflight_bytes").set(4096)
    reg.gauge("/fleet{admission}/open").set(1.0)
    text = M.render_openmetrics({0: reg.snapshot_export("*")})
    snap = T.snapshot_from_metrics(text)
    pool = snap["pools"][(0, "default")]
    assert pool["util"] == 0.8 and pool["idle"] == 0.2
    assert pool["queue"] == 3.0
    assert snap["serve"][(0, 1)]["latency"] == 0.125
    assert snap["net"][(0, 1)]["inflight_bytes"] == 4096
    assert snap["admission"][0]["open"] == 1.0
    frame = T.render_frame(snap)
    assert "engine#1" in frame and "admission: open" in frame


def test_top_cli_once(rt, capsys):
    from repro.obs import top as T

    assert T.main(["--once"]) == 0
    out = capsys.readouterr().out
    assert "fleet-top" in out
