"""Checkpoint: roundtrip, async write, elastic placement, torn writes."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((8, 8)) * 0.5},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 10, s)
    step, r = ckpt.restore(tmp_path)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert int(r["opt"]["step"]) == 7


def test_latest_step_picks_max(tmp_path):
    ckpt.save(tmp_path, 5, _state())
    ckpt.save(tmp_path, 20, _state(1))
    ckpt.save(tmp_path, 15, _state(2))
    assert ckpt.latest_step(tmp_path) == 20
    step, _ = ckpt.restore(tmp_path)
    assert step == 20


def test_async_save(rt, tmp_path):
    fut = ckpt.save_async(tmp_path, 3, _state())
    out = fut.get(timeout=60)
    assert (Path(out) / "manifest.json").exists()
    step, _ = ckpt.restore(tmp_path)
    assert step == 3


def test_torn_write_ignored(tmp_path):
    ckpt.save(tmp_path, 1, _state())
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "leaf_00000.npy").write_bytes(b"garbage")  # no manifest
    assert ckpt.latest_step(tmp_path) == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path / "nope")


def test_restore_with_shardings(tmp_path):
    """Elastic restore path: leaves re-placed via device_put."""
    s = _state()
    ckpt.save(tmp_path, 2, s)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, s)
    _, r = ckpt.restore(tmp_path, shardings=shardings)
    assert r["params"]["w"].sharding == sh


def test_save_restore_gid_local_roundtrip(rt, tmp_path):
    """By-GID checkpointing without a net runtime: save a registered
    object, restore re-binds it under the same symbolic name."""
    from repro.core import agas

    state = {"w": jnp.arange(6.0)}
    agas.default().register(state, name="ckpt-test/obj")
    out = ckpt.save_gid(tmp_path, step=3, target="ckpt-test/obj")
    meta = json.loads((out / "agas.json").read_text())
    assert meta["name"] == "ckpt-test/obj"
    step, gid = ckpt.restore_gid(tmp_path)
    assert step == 3
    got = agas.default().resolve("ckpt-test/obj")
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(6.0))
    assert agas.default().record(gid).name == "ckpt-test/obj"


def test_restore_gid_remote_locality_requires_net(rt, tmp_path):
    """Asking for a target locality with no multi-locality runtime up must
    fail loudly, not silently install the object here."""
    from repro.core import agas

    state = {"w": jnp.ones((2,))}
    agas.default().register(state, name="ckpt-test/needs-net")
    ckpt.save_gid(tmp_path, step=1, target="ckpt-test/needs-net")
    with pytest.raises(RuntimeError, match="bootstrap"):
        ckpt.restore_gid(tmp_path, locality=1)


def test_resume_then_step_trains(rt, tmp_path):
    """Regression: param paths contain '/' — restore must preserve the flat
    pytree so the restored state is immediately steppable."""
    import repro.core as core
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_config("qwen25_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    tr = Trainer(model, AdamWConfig(lr=1e-3, total_steps=10),
                 DataConfig(batch_size=2, seq_len=16),
                 TrainConfig(steps=4, log_every=2, ckpt_every=4,
                             ckpt_dir=str(tmp_path)))
    tr.fit()
    tr2 = Trainer(model, AdamWConfig(lr=1e-3, total_steps=10),
                  DataConfig(batch_size=2, seq_len=16),
                  TrainConfig(steps=2, log_every=1, ckpt_dir=str(tmp_path)))
    assert tr2.resume() == 4
    assert set(tr2.params.keys()) == set(tr.params.keys())
    hist = tr2.fit(2)  # must step without pytree mismatch
    assert len(hist) == 2
