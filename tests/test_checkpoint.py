"""Checkpoint: roundtrip, async write, elastic placement, torn writes."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((8, 8)) * 0.5},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 10, s)
    step, r = ckpt.restore(tmp_path)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert int(r["opt"]["step"]) == 7


def test_latest_step_picks_max(tmp_path):
    ckpt.save(tmp_path, 5, _state())
    ckpt.save(tmp_path, 20, _state(1))
    ckpt.save(tmp_path, 15, _state(2))
    assert ckpt.latest_step(tmp_path) == 20
    step, _ = ckpt.restore(tmp_path)
    assert step == 20


def test_async_save(rt, tmp_path):
    fut = ckpt.save_async(tmp_path, 3, _state())
    out = fut.get(timeout=60)
    assert (Path(out) / "manifest.json").exists()
    step, _ = ckpt.restore(tmp_path)
    assert step == 3


def test_torn_write_ignored(tmp_path):
    ckpt.save(tmp_path, 1, _state())
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "leaf_00000.npy").write_bytes(b"garbage")  # no manifest
    assert ckpt.latest_step(tmp_path) == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path / "nope")


def test_restore_with_shardings(tmp_path):
    """Elastic restore path: leaves re-placed via device_put."""
    s = _state()
    ckpt.save(tmp_path, 2, s)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, s)
    _, r = ckpt.restore(tmp_path, shardings=shardings)
    assert r["params"]["w"].sharding == sh


def test_resume_then_step_trains(rt, tmp_path):
    """Regression: param paths contain '/' — restore must preserve the flat
    pytree so the restored state is immediately steppable."""
    import repro.core as core
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_config("qwen25_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    tr = Trainer(model, AdamWConfig(lr=1e-3, total_steps=10),
                 DataConfig(batch_size=2, seq_len=16),
                 TrainConfig(steps=4, log_every=2, ckpt_every=4,
                             ckpt_dir=str(tmp_path)))
    tr.fit()
    tr2 = Trainer(model, AdamWConfig(lr=1e-3, total_steps=10),
                  DataConfig(batch_size=2, seq_len=16),
                  TrainConfig(steps=2, log_every=1, ckpt_dir=str(tmp_path)))
    assert tr2.resume() == 4
    assert set(tr2.params.keys()) == set(tr.params.keys())
    hist = tr2.fit(2)  # must step without pytree mismatch
    assert len(hist) == 2
