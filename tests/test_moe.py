"""MoE dispatch invariants (the parcel path)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.dist.plan import get_plan
from repro.models.moe import moe_ffn, moe_param_specs
from repro.models.params import init_params

PLAN = get_plan("futurized")


def _layer_params(cfg, rng):
    specs = moe_param_specs(cfg, 1, "")
    p = init_params(specs, rng)
    return {k: v[0] for k, v in p.items()}  # drop the layers dim


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_moe_matches_dense_expert_computation(seed, B):
    """With no drops, the dispatch→GEMM→combine pipeline equals the direct
    per-token mixture Σ_k w_k · expert_k(x) computed densely."""
    cfg = replace(get_config("deepseek_moe_16b", smoke=True),
                  capacity_factor=64.0, n_shared_experts=0)
    rng = jax.random.PRNGKey(seed)
    p = _layer_params(cfg, rng)
    S, D = 8, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, D), jnp.float32) * 0.3
    y, aux = moe_ffn(cfg, PLAN, x, p)

    # dense oracle
    xt = x.reshape(-1, D)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, -1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_in"][e])
        outs.append(h @ p["w_out"][e])
    dense = jnp.stack(outs, 1)  # (T, E, D)
    mix = jnp.einsum("tk,tkd->td", w,
                     jnp.take_along_axis(dense, idx[..., None], axis=1))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, D), np.float32),
                               np.asarray(mix, np.float32), atol=5e-2, rtol=5e-2)
    # E·Σ f_e·P_e ≈ 1 near balance; top-k vs softmax skew keeps it positive
    assert 0.3 < float(aux) < float(cfg.n_experts)


def test_capacity_drops_are_bounded(rng):
    """With cf → 0 the layer must drop (not corrupt) overflow tokens."""
    cfg = replace(get_config("granite_moe_3b_a800m", smoke=True),
                  capacity_factor=1e-6)
    p = _layer_params(cfg, rng)
    x = jax.random.normal(rng, (2, 64, cfg.d_model), jnp.float32)
    y, _ = moe_ffn(cfg, PLAN, x, p)
    assert np.isfinite(np.asarray(y)).all()
    # capacity floor is min(A,16): outputs are not all zero
    assert float(jnp.max(jnp.abs(y))) > 0


def test_shared_experts_always_contribute(rng):
    cfg = replace(get_config("deepseek_moe_16b", smoke=True), capacity_factor=1e-6)
    p = _layer_params(cfg, rng)
    x = jax.random.normal(rng, (1, 4, cfg.d_model), jnp.float32)
    y_with, _ = moe_ffn(cfg, PLAN, x, p)
    p0 = dict(p)
    p0["shared_w_out"] = jnp.zeros_like(p0["shared_w_out"])
    y_without, _ = moe_ffn(cfg, PLAN, x, p0)
    assert float(jnp.max(jnp.abs(y_with - y_without))) > 1e-4
