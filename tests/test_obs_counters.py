"""Histogram percentile math (vs a numpy oracle), percentile timers, the
unified AGAS publish path, and the fleet sampler (repro.obs.sampler)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.counters import CounterRegistry, Histogram, TimerCounter
from repro.obs.sampler import FleetSampler, print_counter_report


# ---------------------------------------------------------------- histogram
@settings(max_examples=60)
@given(st.lists(st.floats(min_value=1e-7, max_value=1e5,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=300),
       st.sampled_from([0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0]))
def test_histogram_quantile_vs_numpy_oracle(samples, q):
    """Log-bucketing guarantees RELATIVE error ≤ growth**0.5 against the
    nearest-rank quantile of the raw samples (positive values)."""
    h = Histogram("/h", growth=1.08)
    for v in samples:
        h.add(v)
    oracle = float(np.sort(np.asarray(samples))[
        int(math.floor(q * (len(samples) - 1)))])
    got = h.quantile(q)
    tol = 1.08 ** 0.5 * 1.0001  # half-bucket geometric error + fp slack
    assert oracle / tol <= got <= oracle * tol


def test_histogram_stats_and_extremes():
    h = Histogram("/h")
    for v in (0.001, 0.01, 0.1, 1.0):
        h.add(v)
    s = h.stats()
    assert s["count"] == 4.0
    assert s["min"] == pytest.approx(0.001)
    assert s["max"] == pytest.approx(1.0)
    assert s["mean"] == pytest.approx(sum((0.001, 0.01, 0.1, 1.0)) / 4)
    # quantiles are clamped into [min, max]
    assert s["min"] <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_histogram_nonpositive_underflow_bucket():
    h = Histogram("/h")
    for v in (-1.0, 0.0, 0.0, 5.0):
        h.add(v)
    assert h.quantile(0.0) == -1.0  # negative min reported as-is
    assert h.quantile(1.0) == pytest.approx(5.0, rel=0.05)


def test_histogram_reset_and_empty():
    h = Histogram("/h")
    assert h.quantile(0.5) == 0.0 and h.stats()["count"] == 0.0
    h.add(3.0)
    h.reset()
    assert h.stats()["count"] == 0.0


def test_timer_percentiles_opt_in():
    plain = TimerCounter("/plain")
    plain.add(0.1)
    assert "p99" not in plain.stats()

    t = TimerCounter("/t", percentiles=True)
    for ms in range(1, 101):
        t.add(ms / 1000.0)
    s = t.stats()
    assert s["count"] == 100.0
    assert s["p50"] == pytest.approx(0.050, rel=0.06)
    assert s["p99"] == pytest.approx(0.099, rel=0.06)
    t.reset()
    assert t.stats()["p50"] == 0.0


def test_registry_timer_percentile_upgrade():
    reg = CounterRegistry()
    t = reg.timer("/up")  # created plain
    assert reg.timer("/up", percentiles=True) is t  # upgraded in place
    t.add(0.25)
    assert t.stats()["p50"] == pytest.approx(0.25, rel=0.05)


def test_registry_snapshot_stats_mixed_kinds():
    reg = CounterRegistry()
    reg.counter("/c").increment(3)
    reg.histogram("/h").add(2.0)
    reg.timer("/t", percentiles=True).add(0.5)
    st_ = reg.snapshot_stats("/*")
    assert st_["/c"] == {"value": 3.0}
    assert st_["/h"]["count"] == 1.0 and "p95" in st_["/h"]
    assert "p99" in st_["/t"]


# ------------------------------------------------- unified AGAS publish path
def test_helpers_publish_into_agas(rt):
    """The satellite fix: get-or-create helpers must publish, exactly like
    register() — counters are visible via AGAS without extra ceremony."""
    from repro.core import agas, counters

    c = counters.default().counter("/obs/test/helper/published")
    c.increment(2)
    assert agas.default().resolve(
        "/counters/obs/test/helper/published") is c
    g = counters.default().gauge("/obs/test/helper/gauge")
    assert agas.default().resolve("/counters/obs/test/helper/gauge") is g
    h = counters.default().histogram("/obs/test/helper/hist")
    assert agas.default().resolve("/counters/obs/test/helper/hist") is h


def test_bare_registry_stays_out_of_agas(rt):
    """Unit-test registries must not leak into the global namespace."""
    from repro.core import agas

    reg = CounterRegistry()
    reg.counter("/obs/test/bare/counter")
    assert not agas.default().contains("/counters/obs/test/bare/counter")


# ------------------------------------------------------------ fleet sampler
def test_sampler_series_and_rate():
    reg = CounterRegistry()
    c = reg.counter("/work/done")
    s = FleetSampler(pattern="/work/*", registry=reg)
    for k in range(1, 5):
        c.increment(10)
        s.sample_once()
    pts = s.series(0, "/work/done")
    assert len(pts) == 4
    assert [v for _, v in pts] == [10.0, 20.0, 30.0, 40.0]
    span = pts[-1][0] - pts[0][0]
    assert s.rate(0, "/work/done") == pytest.approx(30.0 / span)


def test_sampler_rate_across_counter_reset():
    """A reset (negative delta) contributes the post-reset value, not a
    huge negative — the rate stays truthful across restarts."""
    reg = CounterRegistry()
    c = reg.counter("/work/done")
    s = FleetSampler(pattern="/work/*", registry=reg)
    c.increment(100)
    s.sample_once()          # 100
    c.increment(50)
    s.sample_once()          # 150
    c.reset()
    c.increment(20)
    s.sample_once()          # 20  ← reset between samples
    pts = s.series(0, "/work/done")
    span = pts[-1][0] - pts[0][0]
    # counted work: +50 (two increments) then 20 after the reset
    assert s.rate(0, "/work/done") == pytest.approx((50 + 20) / span)


def test_sampler_bounded_depth():
    reg = CounterRegistry()
    c = reg.counter("/w")
    s = FleetSampler(pattern="/w", depth=5, registry=reg)
    for _ in range(12):
        c.increment()
        s.sample_once()
    assert len(s.series(0, "/w")) == 5  # fixed-depth ring


def test_sampler_thread_start_stop():
    reg = CounterRegistry()
    reg.counter("/w").increment()
    s = FleetSampler(pattern="/w", interval=0.01, registry=reg).start()
    try:
        import time

        deadline = time.time() + 5.0
        while s.samples_taken < 3 and time.time() < deadline:
            time.sleep(0.01)
    finally:
        s.stop()
    assert s.samples_taken >= 3


def test_print_counter_report_lines():
    reg = CounterRegistry()
    # exercise through the default-registry path by passing a sampler over
    # a private registry (report reads the default registry only for the
    # local fallback, so feed it via sampler=None + monkey registry)
    import io

    from repro.core import counters as counters_mod

    c = counters_mod.default().counter("/obs/report/demo")
    c.increment(7)
    t = counters_mod.default().timer("/obs/report/lat", percentiles=True)
    t.add(0.002)
    buf = io.StringIO()
    lines = print_counter_report("/obs/report/*", file=buf)
    assert any("/obs/report/demo" in ln for ln in lines)
    assert any("/obs/report/lat" in ln for ln in lines)
    assert buf.getvalue().count("\n") == len(lines)
