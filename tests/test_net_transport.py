"""Transport-tier behavior of the parcelport: coalescing, rendezvous
striping, credit backpressure, and wire failure modes.

Two harnesses:

- an **in-process port pair** — two :class:`Port` instances joined by
  ``socket.socketpair`` lanes, with hooks that collect delivered frames.
  This drives the protocol state machines deterministically (tiny
  budgets, huge coalesce windows) without spawning processes.
- the real **multi-locality bootstrap** (``net_factory``) for the
  failure modes that live above the port: a pending promise must fail
  with ``PortClosed`` (not hang) when its peer dies mid-call, including
  worker↔worker calls failed by the root's DOWN broadcast.

Helper actions are module-level so spawned workers resolve them by
dotted name (``test_net_transport.<fn>``), like test_net_localities.
"""

import socket
import threading
import time

import numpy as np
import pytest

from repro import net as rnet
from repro.net import parcelport as pp
from repro.net import remote as _remote


# ----------------------------------------------- worker-resolvable helpers
def call_slow_peer(rt, dst):
    """Runs on a worker: call ``_slow_sink`` on another worker and report
    how the pending future ends when that worker dies mid-call."""
    try:
        fut = rt.send_parcel(dst, _remote._slow_sink._action_name, None,
                             (b"x" * 64, 30.0))
        fut.get(timeout=25)
        return "completed"
    except pp.PortClosed:
        return "portclosed"


def echo_len(rt, payload):
    return len(payload)


# ------------------------------------------------------- in-process harness
class _Hooks(pp.PortHooks):
    """Collects delivered frames; optionally acks credit like the runtime
    (CREDIT returned for every eager parcel's ``credit_bytes``)."""

    def __init__(self, local_id, auto_credit=True):
        self.local_id = local_id
        self.auto_credit = auto_credit
        self.frames = []
        self.closed = []
        self.chan = None
        self._cv = threading.Condition()

    def deliver(self, fr, channel):
        with self._cv:
            self.frames.append(fr)
            self._cv.notify_all()
        if (self.auto_credit and fr.header.get("t") == pp.PARCEL
                and fr.credit_bytes):
            channel.send_control({"t": pp.CREDIT, "src": self.local_id,
                                  "dst": fr.header["src"],
                                  "n": fr.credit_bytes})

    def route(self, dst):
        if self.chan is None or self.chan.closed:
            raise pp.PortClosed(f"no route to {dst}")
        return self.chan

    def on_close(self, channel):
        with self._cv:
            self.closed.append(channel)
            self._cv.notify_all()

    def wait_frames(self, n, timeout=15.0):
        deadline = time.monotonic() + timeout
        with self._cv:
            while len(self.frames) < n:
                remaining = deadline - time.monotonic()
                assert remaining > 0, \
                    f"timed out with {len(self.frames)}/{n} frames"
                self._cv.wait(remaining)
            return list(self.frames)

    def wait_closed(self, timeout=15.0):
        deadline = time.monotonic() + timeout
        with self._cv:
            while not self.closed:
                remaining = deadline - time.monotonic()
                assert remaining > 0, "channel never closed"
                self._cv.wait(remaining)


@pytest.fixture()
def port_pair():
    """Factory for two in-process ports joined by socketpair lanes."""
    ports = []

    def make(config=None, auto_credit_b=True):
        cfg = config or pp.NetConfig()
        nlanes = 1 + max(0, cfg.stripes)
        hooks_a, hooks_b = _Hooks(0), _Hooks(1, auto_credit=auto_credit_b)
        port_a, port_b = pp.Port(0, hooks_a, cfg), pp.Port(1, hooks_b, cfg)
        ports.extend((port_a, port_b))
        pairs = [socket.socketpair() for _ in range(nlanes)]
        hooks_a.chan = port_a.add_channel(1, [p[0] for p in pairs])
        hooks_b.chan = port_b.add_channel(0, [p[1] for p in pairs])
        return (port_a, hooks_a), (port_b, hooks_b)

    yield make
    for port in ports:
        port.close()


def _parcel_header(src, dst, seq, a="t.noop"):
    return {"t": pp.PARCEL, "src": src, "dst": dst, "seq": seq, "a": a,
            "g": None}


def _wait(pred, timeout=10.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while not pred():
        assert time.monotonic() < deadline, f"timed out waiting for {msg}"
        time.sleep(0.002)


# ------------------------------------------------------------------ eager
def test_eager_round_trip_and_credit_return(port_pair):
    (_pa, ha), (_pb, hb) = port_pair()
    ha.chan.send(_parcel_header(0, 1, 1), ((b"ping",), {}))
    fr = hb.wait_frames(1)[0]
    assert fr.header["a"] == "t.noop"
    assert fr.credit_bytes == fr.wire_bytes > 0
    args, kwargs = pp.decode_payload(fr.header, fr.rest)
    assert args == (b"ping",) and kwargs == {}
    # auto-credit from B drains A's ledger back to zero
    _wait(lambda: ha.chan.inflight_bytes(1) == 0, msg="credit return")


def test_coalescing_packs_parcels_into_multi(port_pair):
    # huge fixed window: everything after the first (quiet-period) frame
    # buffers until the progress thread's deadline flush
    cfg = pp.NetConfig(coalesce_window_us=120_000.0,
                       coalesce_min_window_us=120_000.0)
    (_pa, ha), (_pb, hb) = port_pair(cfg)
    ch = ha.chan
    sent0 = ch.c_parcels_sent.get_value()
    frames0 = ch.c_frames_sent.get_value()
    flush0 = ch.c_co_flushes.get_value()
    packed0 = ch.c_co_parcels.get_value()

    n = 40
    for i in range(n):
        ch.send(_parcel_header(0, 1, i + 1), ((i,), {}))
    got = hb.wait_frames(n)
    # every logical parcel arrives intact and in order...
    assert [pp.decode_payload(f.header, f.rest)[0][0] for f in got] == \
        list(range(n))
    # ...but over far fewer wire frames: at least one MULTI container
    d_parcels = ch.c_parcels_sent.get_value() - sent0
    d_frames = ch.c_frames_sent.get_value() - frames0
    d_flushes = ch.c_co_flushes.get_value() - flush0
    d_packed = ch.c_co_parcels.get_value() - packed0
    assert d_parcels == n
    assert d_flushes >= 1 and d_packed >= 2
    assert d_frames < d_parcels
    # coalesced sub-frames still carry per-parcel credit
    _wait(lambda: ch.inflight_bytes(1) == 0, msg="credit after coalesce")


def test_first_frame_after_quiet_period_is_not_delayed(port_pair):
    cfg = pp.NetConfig(coalesce_window_us=250_000.0,
                       coalesce_min_window_us=250_000.0)
    (_pa, ha), (_pb, hb) = port_pair(cfg)
    t0 = time.monotonic()
    ha.chan.send(_parcel_header(0, 1, 1), ((0,), {}))
    hb.wait_frames(1)
    # an immediate send must not wait out the 250ms coalesce window
    assert time.monotonic() - t0 < 0.2


# ------------------------------------------------------------- rendezvous
def test_rendezvous_stripes_across_bulk_lanes(port_pair):
    cfg = pp.NetConfig(eager_threshold=4096, stripe_chunk=8192, stripes=2)
    (_pa, ha), (_pb, hb) = port_pair(cfg)
    rdv_s0 = ha.chan.c_rdv_sent.get_value()
    rdv_r0 = hb.chan.c_rdv_recv.get_value()

    arr = np.arange(64 * 1024, dtype=np.uint8)
    ha.chan.send(_parcel_header(0, 1, 1), ((arr,), {}))
    fr = hb.wait_frames(1)[0]
    # assembled parcels never consumed eager credit
    assert fr.credit_bytes == 0
    args, _ = pp.decode_payload(fr.header, fr.rest)
    np.testing.assert_array_equal(args[0], arr)
    assert ha.chan.c_rdv_sent.get_value() - rdv_s0 == 1
    assert hb.chan.c_rdv_recv.get_value() - rdv_r0 == 1
    # 64KB in 8KB DATA windows round-robins over both bulk lanes; the
    # priority lane saw only the tiny RTS
    bulk_read = [lane.bytes_read for lane in hb.chan.lanes[1:]]
    assert all(b > 0 for b in bulk_read)
    assert hb.chan.lanes[0].bytes_read < 4096


def test_rendezvous_empty_and_threshold_payloads(port_pair):
    cfg = pp.NetConfig(eager_threshold=1024, stripes=1)
    (_pa, ha), (_pb, hb) = port_pair(cfg)
    big = b"z" * 4096   # over threshold: rendezvous
    small = b"s" * 16   # under: eager
    ha.chan.send(_parcel_header(0, 1, 1), ((big,), {}))
    ha.chan.send(_parcel_header(0, 1, 2), ((small,), {}))
    frames = hb.wait_frames(2)
    by_seq = {f.header["seq"]: pp.decode_payload(f.header, f.rest)[0][0]
              for f in frames}
    assert by_seq[1] == big and by_seq[2] == small


# ----------------------------------------------------------- backpressure
def test_backpressure_defers_runtime_sends_and_drains_on_credit(port_pair):
    cfg = pp.NetConfig(send_budget=8192,
                       coalesce_window_us=50.0, coalesce_min_window_us=50.0)
    (_pa, ha), (_pb, hb) = port_pair(cfg, auto_credit_b=False)
    ch = ha.chan
    deferred0 = ch.c_deferred.get_value()
    payload = b"x" * 4096
    n = 5
    for i in range(n):
        ch.send(_parcel_header(0, 1, i + 1), ((payload,), {}),
                can_block=False)
    # only what fits the 8KB budget went out; the rest parked on the FIFO
    assert ch.c_deferred.get_value() - deferred0 >= 3
    assert ch.inflight_bytes(1) <= cfg.send_budget
    # drain one credit at a time: each ack releases the next deferred frame
    for i in range(n):
        fr = hb.wait_frames(i + 1)[i]
        assert fr.header["seq"] == i + 1  # FIFO order preserved
        hb.chan.send_control({"t": pp.CREDIT, "src": 1, "dst": 0,
                              "n": fr.credit_bytes})
    _wait(lambda: ch.inflight_bytes(1) == 0, msg="ledger drain")


def test_backpressure_blocks_producer_thread_until_credit(port_pair):
    cfg = pp.NetConfig(send_budget=4096)
    (_pa, ha), (_pb, hb) = port_pair(cfg, auto_credit_b=False)
    ch = ha.chan
    blocked0 = ch.c_blocked.get_value()
    payload = b"y" * 4096  # each frame alone exceeds the budget

    def produce():  # plain thread → can_block resolves True
        for i in range(3):
            ch.send(_parcel_header(0, 1, i + 1), ((payload,), {}))

    t = threading.Thread(target=produce, name="producer")
    t.start()
    # frame 1 is admitted (lone over-budget parcel on a quiet wire);
    # frame 2 must block the producer until credit comes back
    hb.wait_frames(1)
    _wait(lambda: ch.c_blocked.get_value() - blocked0 >= 1, msg="block")
    assert t.is_alive()
    assert len(hb.frames) == 1
    for i in range(3):
        fr = hb.wait_frames(i + 1)[i]
        hb.chan.send_control({"t": pp.CREDIT, "src": 1, "dst": 0,
                              "n": fr.credit_bytes})
    t.join(timeout=15.0)
    assert not t.is_alive()
    _wait(lambda: ch.inflight_bytes(1) == 0, msg="ledger drain")


# ------------------------------------------------------ wire failure modes
def test_truncated_frame_mid_stream_closes_channel(port_pair):
    """A peer dying mid-frame must close the channel cleanly: complete
    frames already received are delivered, the torn one is dropped, and
    on_close fires (no hang, no crash, no garbage frame)."""
    cfg = pp.NetConfig(stripes=0)
    hooks = _Hooks(1)
    port = pp.Port(1, hooks, cfg)
    a_sock, b_sock = socket.socketpair()
    hooks.chan = port.add_channel(0, [b_sock])
    try:
        good = pp.encode_frame(_parcel_header(0, 1, 1), ((b"ok",), {}))
        wire = b"".join(bytes(c) for c in good)
        torn = b"".join(
            bytes(c) for c in pp.encode_frame(_parcel_header(0, 1, 2),
                                              ((b"lost",), {})))
        a_sock.sendall(wire + torn[:len(torn) // 2])
        hooks.wait_frames(1)
        time.sleep(0.05)  # let the half-frame sit in the state machine
        a_sock.close()
        hooks.wait_closed()
        assert hooks.chan.closed
        assert len(hooks.frames) == 1
        assert pp.decode_payload(hooks.frames[0].header,
                                 hooks.frames[0].rest)[0] == (b"ok",)
        with pytest.raises(pp.PortClosed):
            hooks.chan.send(_parcel_header(1, 0, 3), ((b"late",), {}))
    finally:
        a_sock.close()
        port.close()


def test_peer_death_mid_rendezvous_drops_assembly(port_pair):
    """EOF while a striped transfer is assembling: the channel closes and
    the half-built _InXfer is discarded, not leaked."""
    cfg = pp.NetConfig(eager_threshold=1024, stripes=0)
    hooks = _Hooks(1)
    port = pp.Port(1, hooks, cfg)
    a_sock, b_sock = socket.socketpair()
    hooks.chan = port.add_channel(0, [b_sock])
    try:
        # hand-run the sender side of the handshake: RTS, await CTS,
        # then send only part of the announced stream and die
        inner = _parcel_header(0, 1, 1)
        inner["blens"], inner["bodylen"] = [], 8192
        rts = pp.encode_frame({"t": pp.RTS, "src": 0, "dst": 1, "x": 7,
                               "size": 8192, "h": inner})
        a_sock.sendall(b"".join(bytes(c) for c in rts))
        cts_h, _ = pp.decode_frame(pp.read_frame(a_sock))
        assert cts_h["t"] == pp.CTS and cts_h["x"] == 7
        _wait(lambda: (0, 7) in port._inx, msg="assembly registered")
        data = pp.encode_frame({"t": pp.DATA, "src": 0, "dst": 1, "x": 7,
                                "o": 0, "n": 4096})
        a_sock.sendall(b"".join(bytes(c) for c in data) + b"\x00" * 4096)
        a_sock.close()
        hooks.wait_closed()
        assert hooks.frames == []          # nothing half-built delivered
        _wait(lambda: not port._inx, msg="assembly discard")
    finally:
        a_sock.close()
        port.close()


# ----------------------------------------------- MULTI container pure codec
def _build_multi(src, dst, parts):
    """Assemble a MULTI container the way Channel._flush_locked does."""
    header = {"t": pp.MULTI, "src": src, "dst": dst, "n": len(parts)}
    hdr = pp._encode_header(header)
    inner = sum(pp._chunks_nbytes(p) for p in parts)
    prefix = bytearray(8)
    pp._U32.pack_into(prefix, 0, 4 + len(hdr) + inner)
    pp._U32.pack_into(prefix, 4, len(hdr))
    chunks = [b"".join((prefix, hdr))]
    for part in parts:
        chunks.extend(part)
    wire = memoryview(b"".join(bytes(c) for c in chunks))
    return header, wire[8 + len(hdr):]


def test_iter_multi_walks_every_subframe():
    parts = [pp.encode_frame(_parcel_header(0, 1, i + 1), ((i,), {}))
             for i in range(3)]
    header, rest = _build_multi(0, 1, parts)
    subs = list(pp.iter_multi(header, rest))
    assert len(subs) == 3
    for i, (shdr, _hb, srest, wire) in enumerate(subs):
        assert shdr["seq"] == i + 1
        assert pp.decode_payload(shdr, srest)[0] == (i,)
        assert wire == pp._chunks_nbytes(parts[i])


def test_failed_parcel_headers_covers_all_carriers():
    parts = [pp.encode_frame(_parcel_header(0, 2, i + 1), ((i,), {}))
             for i in range(2)]
    mh, mrest = _build_multi(0, 2, parts)
    multi = pp.Frame(mh, b"", mrest, mrest.nbytes + 8, 0)
    assert [h["seq"] for h in pp.failed_parcel_headers(multi)] == [1, 2]
    plain = pp.Frame(_parcel_header(0, 2, 9), b"", memoryview(b""), 0, 0)
    assert [h["seq"] for h in pp.failed_parcel_headers(plain)] == [9]
    rts = pp.Frame({"t": pp.RTS, "src": 0, "dst": 2, "x": 1, "size": 10,
                    "h": _parcel_header(0, 2, 5)}, b"", memoryview(b""), 0, 0)
    assert [h["seq"] for h in pp.failed_parcel_headers(rts)] == [5]
    cred = pp.Frame({"t": pp.CREDIT, "src": 0, "dst": 2, "n": 4}, b"",
                    memoryview(b""), 0, 0)
    assert list(pp.failed_parcel_headers(cred)) == []


# ------------------------------------------------- real-bootstrap failures
def test_pending_promise_fails_with_portclosed_on_peer_death(net_factory):
    """Kill a worker while a rendezvous-sized call to it is in flight: the
    caller's future must raise PortClosed promptly, never hang."""
    net = net_factory(2, pools={"default": 2, "io": 1})
    big = np.zeros(8 << 20, dtype=np.uint8)  # forces the rendezvous tier
    fut = net.send_parcel(1, _remote._slow_sink._action_name, None,
                          (big, 30.0))
    net._procs[1].terminate()
    with pytest.raises(pp.PortClosed):
        fut.get(timeout=30)
    # the port must not leak the parked out-transfer for the dead peer
    _wait(lambda: not net._port._outx, msg="out-transfer cleanup")
    _wait(lambda: not any(k[0] == 1 for k in net._port._inx),
          msg="assembly cleanup")


def test_down_broadcast_fails_worker_to_worker_pending(net_factory):
    """Worker 1 has a pending call to worker 2 when worker 2 dies: the
    root's DOWN broadcast must fail it with PortClosed on worker 1."""
    net = net_factory(3, pools={"default": 2, "io": 1})
    outer = rnet.run_on(1, call_slow_peer, 2)
    time.sleep(1.0)  # let the nested worker→worker call get in flight
    net._procs[2].terminate()
    assert outer.get(timeout=60) == "portclosed"


def test_backpressure_releases_after_drain(net_factory):
    """Flood a slow consumer past the budget: inflight bytes stay bounded
    while producers block, then drain to zero and the link still works."""
    cfg = rnet.NetConfig(send_budget=64 * 1024)
    net = net_factory(2, pools={"default": 2, "io": 2}, config=cfg)
    ch = net._conns[1]
    blocked0 = ch.c_blocked.get_value()
    deferred0 = ch.c_deferred.get_value()

    samples = []
    stop = threading.Event()

    def sample():
        while not stop.is_set():
            samples.append(ch.inflight_bytes(1))
            time.sleep(0.0005)

    sampler = threading.Thread(target=sample, name="sampler")
    sampler.start()
    payload = b"f" * 8192
    try:
        for _ in range(80):  # MainThread: blocks when over budget
            net.send_parcel(1, _remote._slow_sink._action_name, None,
                            (payload, 0.002), want_result=False)
    finally:
        stop.set()
        sampler.join(timeout=5.0)
    engaged = (ch.c_blocked.get_value() - blocked0) + \
        (ch.c_deferred.get_value() - deferred0)
    assert engaged > 0, "flood never hit the budget"
    assert max(samples) <= cfg.send_budget
    _wait(lambda: ch.inflight_bytes(1) == 0, timeout=30.0,
          msg="post-flood drain")
    # release after drain: the link is healthy, not wedged
    assert rnet.run_on(1, echo_len, b"abc").get(timeout=30) == 3
