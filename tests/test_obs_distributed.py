"""Fleet tracing across real OS-process localities: causal links between
sender and receiver spans, clock-corrected merge, remote counter stats."""

import pytest

from repro.obs import export, trace


# Helper action at module level: workers resolve it by dotted name.
def touch_percentile_timer(rt):
    from repro.core import counters

    counters.default().timer("/obs/remote/lat", percentiles=True).add(0.01)
    return True


@pytest.fixture(autouse=True)
def _clean_tracer():
    trace.disable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


def test_three_locality_merged_trace_causal_links(net_factory, tmp_path):
    """The acceptance-criteria scenario: a 3-locality run exports ONE merged
    Chrome trace where cross-locality parcel flow events link sender and
    receiver spans, and every remote execute span carries its parent
    parcel's flow id."""
    from repro import net as rnet
    from repro.net import remote

    net = net_factory(3)
    export.enable_fleet(net)
    try:
        # place objects at both workers, then touch them: parcels flow
        # root→1, root→2, and worker→root (the AGAS publish hooks)
        remote.run_on(1, remote._install_state, "/obs/t/a",
                      {"v": 1}).get(timeout=60)
        remote.run_on(2, remote._install_state, "/obs/t/b",
                      {"v": 2}).get(timeout=60)
        assert rnet.fetch("/obs/t/a") == {"v": 1}
        assert rnet.fetch("/obs/t/b") == {"v": 2}

        path = tmp_path / "merged.json"
        tr = export.export_chrome_trace(str(path), net=net)
    finally:
        export.disable_fleet(net)

    assert path.exists() and path.stat().st_size > 0
    pids = {e["pid"] for e in tr["traceEvents"]}
    assert pids == {0, 1, 2}  # all three localities in ONE trace

    # every remote execute span's parent == a flow id that some OTHER
    # locality opened with a flow-start bound to its send span
    starts = {e["id"]: e["pid"] for e in tr["traceEvents"] if e["ph"] == "s"}
    execs = [e for e in tr["traceEvents"]
             if e["ph"] == "X" and e["name"].startswith("execute:")
             and "parent" in e.get("args", {})]
    assert execs, "no linked execute spans recorded"
    cross = 0
    for e in execs:
        parent = e["args"]["parent"]
        assert parent in starts, f"orphan execute span: {e}"
        if starts[parent] != e["pid"]:
            cross += 1
    assert cross > 0, "no cross-locality causal link"

    # flow audit: at least one complete sender→receiver arrow between
    # distinct localities in both directions of the root
    links = export.flow_links(tr)
    complete = {k: v for k, v in links.items()
                if v["src"] is not None and v["dst"] is not None
                and v["src"] != v["dst"]}
    assert complete
    assert {(v["src"], v["dst"]) for v in complete.values()} >= {(0, 1), (0, 2)}


def test_clock_offset_roundtrip(net_factory):
    net = net_factory(2)
    off = export.clock_offset(net, 1)
    assert off != 0.0  # distinct perf_counter epochs
    assert export.clock_offset(net, net.locality) == 0.0
    # corrected receive must land within the probe's RTT window of the
    # send: loopback offsets are stable to well under a second
    off2 = export.clock_offset(net, 1)
    assert abs(off - off2) < 0.5


def test_remote_counter_stats_have_percentiles(net_factory):
    from repro import net as rnet
    from repro.net import remote

    net = net_factory(2)
    remote.run_on(1, touch_percentile_timer).get(timeout=60)
    stats = rnet.query_counter_stats(1, "/obs/remote/*")
    assert stats["/obs/remote/lat"]["count"] == 1.0
    assert "p99" in stats["/obs/remote/lat"]


def test_fleet_sampler_over_localities(net_factory):
    from repro.obs.sampler import FleetSampler

    net = net_factory(2)
    s = FleetSampler(pattern="/net{locality*", net=net)
    s.sample_once()
    s.sample_once()
    locs = {loc for loc, _name in s.keys()}
    assert locs == {0, 1}  # histories for every locality
