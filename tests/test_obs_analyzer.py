"""Critical-path analyzer, SLOW blame, and the anomaly flight recorder
(repro.obs.critical_path / attribution / recorder / analyze).

Two halves:

- deterministic unit tests over hand-built merged traces (known gaps,
  known classes, injected negative edges);
- the ISSUE 9 acceptance scenarios on a real 3-locality fleet: >=95%
  attribution of every sampled request, a batch flood tripping the
  controller's ``dump_trace`` trigger into a cross-locality anomaly
  trace, and a skewed worker clock whose edges clamp instead of running
  backwards.
"""

import json
import os

import numpy as np
import pytest

import repro.core as core
from repro import net as rnet
from repro.obs import attribution, export, trace
from repro.obs import critical_path as cpm
from repro.serve.engine import ServeConfig
from repro.serve.router import TIER_BATCH, TIER_INTERACTIVE, Router

pytestmark = pytest.mark.usefixtures("rt")


@pytest.fixture(autouse=True)
def _clean_tracer():
    trace.disable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


# ------------------------------------------------------- synthetic traces
def _ev(events, **kw):
    events.append(kw)
    return kw


def _span(events, name, pid, tid, ts, dur, **args):
    return _ev(events, name=name, cat="t", ph="X", pid=pid, tid=tid,
               ts=float(ts), dur=float(dur), args=args)


def _remote_trace(engine_shift=0.0, decode_dur=400.0, tag="r0:1"):
    """One interactive request dispatched from locality 0 to an engine on
    locality 1, completion delivered back — both wire legs present.
    ``engine_shift`` slides every locality-1 timestamp (emulates residual
    clock-correction error); ``decode_dur`` stretches the decode step."""
    ev = []
    sh = float(engine_shift)
    _span(ev, "router/submit", 0, 1, 0, 300, sid="0:1", req=tag,
          slo="interactive")
    _span(ev, "send:_fleet_submit", 0, 1, 50, 200, sid="0:2", parent="0:1")
    _ev(ev, name="send:_fleet_submit", cat="t", ph="s", pid=0, tid=1,
        ts=50.0, id="0:3")
    _span(ev, "execute:_fleet_submit", 1, 9, 800 + sh, 100, sid="1:1",
          parent="0:3")
    _ev(ev, name="execute:_fleet_submit", cat="t", ph="f", pid=1, tid=9,
        ts=800.0 + sh, id="0:3", bp="e")
    _ev(ev, name="request", cat="serve", ph="b", pid=1, tid=9,
        ts=850.0 + sh, id="1:1", args={"req": tag, "slo": "interactive"})
    _span(ev, "prefill", 1, 10, 1200 + sh, 1000, sid="1:2", req=tag)
    _span(ev, "decode_step", 1, 11, 2500 + sh, decode_dur, sid="1:3",
          reqs=[tag])
    _ev(ev, name="request", cat="serve", ph="e", pid=1, tid=11,
        ts=3000.0 + sh, id="1:1", args={"req": tag})
    _span(ev, "relay/done", 1, 11, 3050 + sh, 100, sid="1:4", req=tag)
    _span(ev, "send:_deliver_done", 1, 11, 3060 + sh, 80, sid="1:6",
          parent="1:4")
    _ev(ev, name="send:_deliver_done", cat="t", ph="s", pid=1, tid=11,
        ts=3060.0 + sh, id="1:5")
    _span(ev, "execute:_deliver_done", 0, 2, 3900, 150, sid="0:4",
          parent="1:5")
    _ev(ev, name="execute:_deliver_done", cat="t", ph="f", pid=0, tid=2,
        ts=3900.0, id="1:5", bp="e")
    return {"traceEvents": ev}


def _gated_local_trace(tag="r0:7"):
    """A batch request parked at the gate, then KV-pool stalled: the two
    Waiting causes, plus prefill/ready starvation, on one locality."""
    ev = []
    _ev(ev, name="router/gated", cat="serve", ph="i", pid=0, tid=1,
        ts=100.0, s="t", args={"req": tag, "slo": "batch"})
    _span(ev, "router/submit", 0, 1, 5000, 200, sid="0:9", req=tag,
          slo="batch")
    _ev(ev, name="request", cat="serve", ph="b", pid=0, tid=3, ts=5300.0,
        id="0:10", args={"req": tag, "slo": "batch"})
    _span(ev, "prefill", 0, 4, 6000, 800, sid="0:11", req=tag)
    _ev(ev, name="admit_stall", cat="serve", ph="i", pid=0, tid=3,
        ts=7000.0, args={"req": tag})
    _span(ev, "decode_step", 0, 3, 8000, 300, sid="0:12", reqs=[tag])
    _ev(ev, name="request", cat="serve", ph="e", pid=0, tid=3, ts=8400.0,
        id="0:10", args={"req": tag})
    return {"traceEvents": ev}


# ------------------------------------------------------------- unit tests
def test_critical_path_tiles_the_full_wall_time():
    cp = cpm.critical_path(_remote_trace(), "r0:1")
    assert cp is not None and cp.slo == "interactive"
    # tiled: every microsecond lands in exactly one classified interval
    assert cp.fraction == pytest.approx(1.0)
    assert cp.residual_us == pytest.approx(0.0)
    assert cp.clamped_count == 0
    assert sum(cp.by_class.values()) == pytest.approx(cp.total_us)
    for iv in cp.intervals:
        assert iv.t1 >= iv.t0 and iv.cls in cpm.SLOW_CLASSES
    # contiguous coverage, in order
    for a, b in zip(cp.intervals, cp.intervals[1:]):
        assert b.t0 == pytest.approx(a.t1)


def test_cross_locality_wire_time_classified_latency():
    cp = cpm.critical_path(_remote_trace(), "r0:1")
    assert cp.localities() == {0, 1}
    wires = [iv for iv in cp.intervals if iv.cls == "L"]
    assert len(wires) == 2  # submit leg and completion leg
    assert cp.by_class["L"] == pytest.approx(500.0 + 750.0)
    assert cp.by_class["work"] == pytest.approx(1400.0)
    # starvation on both queues, work on prefill+decode
    whats = [(iv.cls, iv.what) for iv in cp.intervals]
    assert ("S", "prefill queue") in whats
    assert ("S", "ready queue") in whats


def test_gate_and_pool_stalls_classified_waiting():
    cp = cpm.critical_path(_gated_local_trace(), "r0:7")
    whats = [(iv.cls, iv.what) for iv in cp.intervals]
    assert ("W", "admission gate") in whats
    assert ("W", "kv-pool stall") in whats
    assert cp.slo == "batch"
    # the gate park dominates this request: W is the top class
    assert max(cp.by_class, key=cp.by_class.get) == "W"
    assert cp.fraction == pytest.approx(1.0)


def test_negative_edges_clamped_and_counted_not_silent():
    tr = _remote_trace(engine_shift=-800.0)
    edges = cpm.flow_edges(tr)
    clamped = [e for e in edges if e["clamped"]]
    assert clamped and all(e["raw_us"] < 0.0 for e in clamped)
    assert all(e["transit_us"] >= 0.0 for e in edges)  # never backwards
    cp = cpm.critical_path(tr, "r0:1")
    assert cp.clamped_count >= 1 and cp.clamped_us > 0.0
    assert all(iv.t1 >= iv.t0 for iv in cp.intervals)
    assert cp.fraction >= 0.95  # still fully tiled after clipping


def test_mark_critical_path_injects_anomaly_track():
    tr = _remote_trace()
    cp = cpm.critical_path(tr, "r0:1")
    cpm.mark_critical_path(tr, cp)
    marked = [e for e in tr["traceEvents"] if e.get("cat") == "anomaly"]
    assert len(marked) == len(cp.intervals)
    assert {e["tid"] for e in marked} == {cpm.CP_TID}
    assert {e["pid"] for e in marked} == {0, 1}
    names = [e["name"] for e in tr["traceEvents"]
             if e.get("ph") == "M" and e.get("tid") == cpm.CP_TID]
    assert len(names) == 2  # one blame track per locality
    assert tr["critical_path"]["req"] == "r0:1"


def test_slow_report_groups_by_tier_and_diffs():
    a = {"traceEvents": (_remote_trace()["traceEvents"]
                         + _gated_local_trace()["traceEvents"])}
    ra = attribution.slow_report(a)
    assert ra["requests"] == 2 and not ra["lossy"]
    assert set(ra["tiers"]) == {"interactive", "batch"}
    t = ra["tiers"]["interactive"]
    assert t["attributed_fraction"]["min"] >= 0.95
    assert sum(t["shares"].values()) == pytest.approx(1.0)
    # B stretches the decode step by 200us: the diff shows work moving
    rb = attribution.slow_report(_remote_trace(decode_dur=600.0))
    d = attribution.diff_reports(attribution.slow_report(_remote_trace()),
                                 rb)
    assert d["tiers"]["interactive"]["delta_us"]["work"] == \
        pytest.approx(200.0)
    # renderers don't choke
    assert "interactive" in attribution.format_report(ra)
    assert "wire" in attribution.format_critical_path(
        cpm.critical_path(_remote_trace(), "r0:1"))


def test_fold_into_counters_feeds_blame_histograms():
    cps = attribution.analyze_requests(_remote_trace())
    reg = core.counters.CounterRegistry()
    assert attribution.fold_into_counters(cps, registry=reg) == 1
    stats = reg.snapshot_stats("/obs{blame/interactive}*")
    for cls in ("work", "starvation", "latency", "overhead", "waiting"):
        assert f"/obs{{blame/interactive}}/{cls}" in stats
    assert stats["/obs{blame/interactive}/total"]["count"] == 1.0
    # latency histogram holds seconds: 1.25ms of wire time
    assert stats["/obs{blame/interactive}/latency"]["p50"] == \
        pytest.approx(1.25e-3, rel=0.2)


def test_print_counter_report_includes_blame_sorted():
    from repro.obs.sampler import print_counter_report

    attribution.fold_into_counters(attribution.analyze_requests(
        _remote_trace(tag="r0:42")))
    lines = print_counter_report(pattern="/no/such/counter*", net=None)
    body = [ln for ln in lines[1:] if ln.startswith("L0 ")]
    # blame histograms ride along regardless of the asked-for pattern...
    assert any("/obs{blame/interactive}/latency" in ln for ln in body)
    # ...with percentile cells populated, sorted by counter path
    blame_line = next(ln for ln in body
                      if "/obs{blame/interactive}/total" in ln)
    assert blame_line.rstrip()[-1] != "-"
    names = [ln.split()[1] for ln in body]
    assert names == sorted(names)


def test_ring_drop_counters_and_lossy_header():
    trace.enable(capacity=64)
    for i in range(200):
        trace.instant("spam", "t", i=i)
    assert trace.recorded_events() == 64
    assert trace.dropped_events() == 136
    vals = dict(core.counters.query("/obs{locality#0}/trace/*"))
    assert vals["/obs{locality#0}/trace/events"] == 64.0
    assert vals["/obs{locality#0}/trace/dropped"] == 136.0
    tr = export.merged_trace(None)
    assert tr["lossy"] is True
    assert any(n > 0 for n in tr["ring_drops"].values())
    assert attribution.slow_report(tr)["lossy"] is True


def test_analyze_cli(tmp_path, capsys):
    from repro.obs import analyze

    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_remote_trace()))
    b.write_text(json.dumps(_remote_trace(decode_dur=600.0)))

    assert analyze.main([str(a), "--requests"]) == 0
    assert "r0:1" in capsys.readouterr().out

    assert analyze.main([str(a), "--critical-path", "r0:1"]) == 0
    out = capsys.readouterr().out
    assert "wire" in out and "prefill" in out

    assert analyze.main([str(a), "--critical-path", "nope"]) == 1
    capsys.readouterr()

    assert analyze.main([str(a), "--slow-report", "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["requests"] == 1 and "interactive" in rep["tiers"]

    assert analyze.main(["--diff", str(a), str(b)]) == 0
    assert "work" in capsys.readouterr().out

    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert analyze.main([str(empty)]) == 1  # nothing to chew on


# --------------------------------------------- 3-locality fleet scenarios
@pytest.fixture(scope="module")
def fleet(rt):
    pools = {"default": 4, "prefill": 2, "io": 1}
    with rnet.running(3, pools=pools, worker_pools=pools) as net:
        scfg = ServeConfig(max_batch=2, cache_len=96, max_new_tokens=24)
        router = Router.over_localities(
            net, "qwen25_3b", scfg, smoke=True, plan="serve",
            tiers={"engine#1": TIER_INTERACTIVE, "engine#2": TIER_BATCH})
        yield net, router


def _prompts(n, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 512, size=rng.integers(4, 16)).tolist()
            for _ in range(n)]


def test_traced_fleet_attribution_covers_95_percent(fleet):
    """Acceptance: on a traced 3-locality run the analyzer attributes
    >=95% of every sampled request's admission->finish wall time, with
    the residual explicit, and remote requests span >=2 localities."""
    net, router = fleet
    export.enable_fleet(net)
    try:
        futs = [router.submit(p, slo=TIER_INTERACTIVE)
                for p in _prompts(2, seed=3)]
        futs += [router.submit(p, slo=TIER_BATCH) for p in _prompts(2)]
        futs += [router.submit(p) for p in _prompts(1, seed=5)]
        for f in futs:
            assert len(f.get(timeout=600)) == 25
        tr = export.merged_trace(net)
    finally:
        export.disable_fleet(net)

    idx = cpm.TraceIndex(tr)
    tags = cpm.request_ids(idx)
    assert len(tags) >= 5
    cps = attribution.analyze_requests(idx)
    assert set(cps) == set(tags)
    for tag, cp in cps.items():
        assert cp.fraction >= 0.95, (tag, cp.summary())
        s = cp.summary()
        assert s["attributed_us"] + s["residual_us"] >= 0.95 * s["total_us"]
    # the interactive tier lives on locality 1: its paths cross the wire
    remote_cps = [cp for cp in cps.values() if cp.slo == TIER_INTERACTIVE]
    assert remote_cps
    assert all(len(cp.localities()) >= 2 for cp in remote_cps)
    assert all(cp.by_class["L"] > 0.0 for cp in remote_cps)
    # clock-corrected edges never go backwards in the merged trace
    assert all(e["transit_us"] >= 0.0 for e in cpm.flow_edges(idx))
    # per-tier report covers what we submitted
    rep = attribution.slow_report(idx, cps)
    assert {TIER_INTERACTIVE, TIER_BATCH} <= set(rep["tiers"])
    # the live p99 gauge the flight-recorder trigger polls is published
    p99s = dict(core.counters.query("/serve{*}/request/latency/p99"))
    assert p99s and max(p99s.values()) > 0.0


def test_batch_flood_trips_flight_recorder_cross_locality(fleet, tmp_path):
    """Acceptance: an injected batch flood closes the admission gate; the
    controller's trigger rule fires ``dump_trace``; the exported anomaly
    trace is fleet-merged with the offender's critical path marked across
    >=2 localities."""
    from repro.fleet import AdmissionController, FleetController
    from repro.obs.recorder import FlightRecorder

    net, router = fleet
    rec = FlightRecorder(net, out_dir=str(tmp_path), capacity=16384,
                         rearm_s=120.0, probes=2)
    rec.start()
    sig = {"occ": 0.95}
    flood = []
    try:
        # real traffic first, so the frozen rings hold completed requests
        for f in [router.submit(p, slo=TIER_INTERACTIVE)
                  for p in _prompts(3, seed=11)]:
            assert len(f.get(timeout=600)) == 25

        router.admission = AdmissionController(lambda: sig["occ"],
                                               high=0.85, low=0.60)
        flood = [router.submit(p, slo=TIER_BATCH)
                 for p in _prompts(4, seed=13)]
        assert router.gated_depth() == 4

        controller = FleetController(net, router, interval=60.0)
        rec.install(controller, gate_trigger=True, error_trigger=False,
                    sustain=1)
        controller.tick()  # gate closed -> recorder/gate_closed fires

        path = rec.last_path
        assert path is not None and os.path.exists(path)
        assert rec.c_dumps.get_value() == 1.0
        with open(path) as f:
            tr = json.load(f)
        assert tr["anomaly"]["reason"] == "controller"
        assert tr["anomaly"]["detail"]["gated_depth"] >= 1
        assert tr["anomaly"]["requests_analyzed"] >= 3
        # the marked offender crosses the wire and is >=95% attributed
        off = tr["critical_path"]
        assert off["req"] == rec.last_offender
        assert len(off["localities"]) >= 2
        assert off["fraction"] >= 0.95
        marked = [e for e in tr["traceEvents"] if e.get("cat") == "anomaly"]
        assert marked and {e["tid"] for e in marked} == {cpm.CP_TID}
        assert {e["pid"] for e in marked} >= set(off["localities"])
        # a second trigger inside the re-arm window must not dump again
        controller.tick()
        assert rec.c_dumps.get_value() == 1.0
        # the dump folded blame into the live histograms
        blame = core.counters.default().snapshot_stats("/obs{blame/*")
        assert any("/total" in k for k in blame)
    finally:
        sig["occ"] = 0.10  # reopen the gate and drain the park
        router.release_gated()
        for f in flood:
            assert len(f.get(timeout=600)) == 25
        router.admission = None
        rec.stop()


def test_skewed_worker_clock_edges_clamp_not_reverse(fleet):
    """Acceptance satellite: skew one worker's probe clock by +50ms —
    min-RTT correction then maps its events too early, so wire edges into
    it would run backwards.  The analyzer must clamp (and count) those,
    never emit a negative duration."""
    from repro.net import remote

    net, router = fleet
    remote.run_on(1, export._obs_set_probe_skew, 0.05).get(timeout=60)
    export.enable_fleet(net)
    try:
        for f in [router.submit(p, slo=TIER_INTERACTIVE)
                  for p in _prompts(2, seed=17)]:
            assert len(f.get(timeout=600)) == 25
        tr = export.merged_trace(net)
    finally:
        export.disable_fleet(net)
        remote.run_on(1, export._obs_set_probe_skew, 0.0).get(timeout=60)

    edges = cpm.flow_edges(tr)
    into_skewed = [e for e in edges if e["dst"] == 1 and e["src"] != 1]
    assert into_skewed
    # 50ms of injected error dwarfs real loopback transit: edges into the
    # skewed worker run backwards raw, and every one is clamped + flagged
    assert any(e["clamped"] and e["raw_us"] < 0.0 for e in into_skewed)
    assert all(e["transit_us"] >= 0.0 for e in edges)
    cps = attribution.analyze_requests(tr)
    assert cps
    for cp in cps.values():
        assert all(iv.t1 >= iv.t0 for iv in cp.intervals)
        assert cp.fraction >= 0.95
    assert sum(cp.clamped_count for cp in cps.values()) >= 1


def test_lossy_report_quantifies_drops_per_locality():
    report = attribution.slow_report({
        "traceEvents": [], "lossy": True,
        "ring_drops": {"0/worker-0": 100, "0/worker-1": 36, "2/pump": 7},
    })
    assert report["ring_drops"] == {"0": 136, "2": 7}
    head = attribution.format_report(report).splitlines()[0]
    assert "LOSSY TRACE" in head and "L0=136" in head and "L2=7" in head


def test_print_counter_report_marks_unreachable_peer(monkeypatch):
    from repro.net import remote as _remote
    from repro.obs.sampler import print_counter_report

    def fake_sweep(locality, pattern, timeout=60.0):
        assert locality is None, "report must use the fault-tolerant sweep"
        if "blame" in pattern:
            return {0: {}, 3: {"error": "PortClosed('peer 3 gone')"}}
        return {0: {"/fleet{x}/ok": {"value": 1.0}},
                3: {"error": "PortClosed('peer 3 gone')"}}

    monkeypatch.setattr(_remote, "query_counter_stats", fake_sweep)
    lines = print_counter_report(pattern="*", net=object())
    assert any(ln.startswith("locality#3 UNREACHABLE") for ln in lines)
    assert any("/fleet{x}/ok" in ln for ln in lines)
