"""Executor hierarchy + resource partitioner (HPX P6/P2).

Covers the executor protocol (post/async_execute/sync_execute/
bulk_async_execute), named-pool routing with per-pool counters, pool
isolation (a saturated "io" pool cannot delay a PRIORITY_HIGH task on
"default"), the legacy ExecutionPolicy(kind=...) deprecation shim, and the
consumer contracts (serve prefill / data prefetch / checkpoint writes on
their designated pools).
"""

import threading
import time
import warnings

import jax
import numpy as np
import pytest

import repro.core as core
from repro.core import counters
from repro.core.executor import (
    ExecutionPolicy,
    Executor,
    MeshExecutor,
    PriorityExecutor,
    SequencedExecutor,
    ThreadPoolExecutor,
    get_executor,
    mesh_policy,
    par,
    vec,
)
from repro.core.future import Future
from repro.core.scheduler import PRIORITY_HIGH, Runtime


def _executed(pool: str) -> float:
    try:
        return counters.get_value(f"/scheduler{{{pool}}}/tasks/executed")
    except KeyError:
        return 0.0


# ----------------------------------------------------------- executor protocol
def test_sequenced_executor_runs_inline():
    ex = SequencedExecutor()
    tid = []
    f = ex.async_execute(lambda: tid.append(threading.get_ident()) or 41)
    assert f.is_ready() and f.get() == 41
    assert tid == [threading.get_ident()]
    assert ex.sync_execute(lambda a, b: a + b, 20, 22) == 42


def test_sequenced_executor_captures_exceptions():
    f = SequencedExecutor().async_execute(lambda: 1 / 0)
    assert f.has_exception()
    with pytest.raises(ZeroDivisionError):
        f.get()


def test_threadpool_executor_async_and_bulk(rt):
    ex = ThreadPoolExecutor("default")
    assert ex.async_execute(lambda a: a * 2, 21).get() == 42
    futs = ex.bulk_async_execute(lambda lo, hi: list(range(lo, hi)),
                                 [(0, 3), (3, 5)])
    assert [f.get() for f in futs] == [[0, 1, 2], [3, 4]]
    assert ex.parallelism == rt.pool().num_workers


def test_threadpool_executor_post_fire_and_forget(rt):
    done = threading.Event()
    ThreadPoolExecutor("default").post(done.set)
    assert done.wait(5.0)


def test_post_exception_does_not_kill_the_worker():
    """A raising fire-and-forget task must be reported (tasks/failed), not
    take down the worker — on a 1-worker pool a dead worker would hang
    every subsequent task forever."""
    with Runtime(pools={"lone": 1}, pool_name="lone") as rt:
        ex = rt.get_executor("lone")
        ex.post(lambda: 1 / 0)
        # the pool must still make progress afterwards
        assert ex.async_execute(lambda: "alive").get(timeout=10.0) == "alive"
        assert counters.get_value("/scheduler{lone}/tasks/failed") >= 1


def test_priority_executor_jumps_the_queue():
    with Runtime(pools={"solo": 1}, pool_name="solo") as rt:
        started = threading.Event()
        release = threading.Event()
        order = []
        ex = rt.get_executor("solo")
        hi = rt.get_executor("solo", priority=PRIORITY_HIGH)
        assert isinstance(hi, PriorityExecutor)
        # head task occupies the single worker while we enqueue the race
        ex.post(lambda: (started.set(), release.wait(10.0)))
        assert started.wait(5.0)
        normals = [ex.async_execute(lambda i=i: order.append(("n", i)))
                   for i in range(4)]
        urgent = hi.async_execute(lambda: order.append(("hi", 0)))
        release.set()
        urgent.get(timeout=10.0)
        [f.get(timeout=10.0) for f in normals]
        assert order[0] == ("hi", 0)  # high priority ran before the backlog


def test_mesh_executor_is_device_plane():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    ex = MeshExecutor(mesh, "data")
    assert ex.plane == "device"
    out = np.asarray(ex.vmap_apply(lambda x: x * 2, np.arange(8)))
    assert list(out) == [2 * i for i in range(8)]
    assert int(ex.sum_total(np.arange(8))) == 28


# ------------------------------------------------------- resource partitioner
def test_partitioner_creates_named_pools_with_counters():
    with Runtime(pools={"default": 2, "io": 1, "prefill": 1}) as rt:
        assert set(rt.pool_names()) == {"default", "io", "prefill"}
        before = {p: _executed(p) for p in ("default", "io", "prefill")}
        assert rt.get_executor("io").async_execute(lambda: "io").get() == "io"
        assert rt.get_executor("prefill").async_execute(lambda: "pf").get() == "pf"
        assert _executed("io") == before["io"] + 1
        assert _executed("prefill") == before["prefill"] + 1
        assert _executed("default") == before["default"]


def test_get_executor_unknown_pool_raises_without_fallback():
    with Runtime(pools={"default": 1}) as rt:
        with pytest.raises(KeyError):
            rt.get_executor("nope").async_execute(lambda: 1).get()
        assert rt.get_executor("nope", fallback="default").async_execute(
            lambda: 1).get() == 1


def test_add_pool_is_idempotent_elastic_partitioning():
    with Runtime(pools={"default": 1}) as rt:
        p1 = rt.add_pool("late", 2)
        p2 = rt.add_pool("late", 5)  # no resize: same pool back
        assert p1 is p2 and p1.num_workers == 2
        assert rt.get_executor("late").async_execute(lambda: 9).get() == 9


def test_init_partitions_default_and_io_pools():
    # module-level init() must partition an io plane even unconfigured
    rt = core.get_runtime()
    names = set(rt.pool_names())
    assert "default" in names and "io" in names


def test_explicit_partition_is_honored_exactly():
    """init(pools={...}) without a 'default' entry must not grow hidden
    pools; affinity consumers fall back to the runtime's default pool."""
    with Runtime(pools={"compute": 2}, pool_name="compute") as rt:
        assert rt.pool_names() == ["compute"]
        assert rt.pool().name == "compute"
        # "io"/"default" affinity degrades to the default pool, not KeyError
        assert rt.get_executor("io", fallback="default").async_execute(
            lambda: 1).get() == 1


def test_priority_wrapped_post_failure_stays_loud():
    """post() through a PriorityExecutor must report like a plain post —
    never an exception parked in an unread Future."""
    with Runtime(pools={"pp": 1}, pool_name="pp") as rt:
        before = counters.get_value("/scheduler{pp}/tasks/failed")
        rt.get_executor("pp", priority=PRIORITY_HIGH).post(lambda: 1 / 0)
        assert rt.get_executor("pp").async_execute(lambda: "ok").get(
            timeout=10.0) == "ok"
        assert counters.get_value("/scheduler{pp}/tasks/failed") == before + 1


def test_pool_isolation_io_saturation_cannot_delay_default():
    """A saturated 1-worker io pool must not delay PRIORITY_HIGH work on
    the compute pool (the partitioner's whole point)."""
    with Runtime(pools={"default": 2, "io": 1}) as rt:
        release = threading.Event()
        io_ex = rt.get_executor("io")
        io_futs = [io_ex.async_execute(lambda: release.wait(10.0))
                   for _ in range(8)]  # io backlog >> its capacity
        t0 = time.perf_counter()
        hi = rt.get_executor("default", priority=PRIORITY_HIGH)
        assert hi.async_execute(lambda: "fast").get(timeout=5.0) == "fast"
        latency = time.perf_counter() - t0
        release.set()
        [f.get(timeout=30.0) for f in io_futs]
        assert latency < 1.0, f"io backlog leaked into default: {latency:.3f}s"


# ------------------------------------------------------------- policy objects
def test_policies_are_pure_rewrites():
    p = par.with_(chunk_size=64, priority=PRIORITY_HIGH)
    assert (p.chunk_size, p.priority) == (64, PRIORITY_HIGH)
    assert par.chunk_size is None and par.priority is None  # original untouched
    assert par.with_(task=True).task and not par.task
    with pytest.raises(AttributeError):
        par.chunk_size = 3


def test_policy_on_executor_binds_resources(rt):
    before = _executed("io")
    bound = par.on(rt.get_executor("io", fallback="default"))
    from repro.core import algorithms as alg

    assert alg.reduce(bound, list(range(100))) == sum(range(100))
    assert _executed("io") > before  # chunks ran on the bound pool


def test_legacy_kind_spelling_warns_and_maps():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p = ExecutionPolicy(kind="par", chunk_size=7)
    assert p.flavor == "par" and p.chunk_size == 7
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_legacy_mesh_spellings_warn_and_map():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p1 = ExecutionPolicy("mesh", mesh=mesh, axis="data")
        p2 = par.on(mesh)  # raw mesh instead of an executor
    assert p1.kind == p2.kind == "mesh"
    assert isinstance(p1.executor, MeshExecutor)
    assert isinstance(p2.executor, MeshExecutor)
    assert p1.mesh is mesh and p1.axis == "data"  # legacy readers
    assert sum(issubclass(x.category, DeprecationWarning) for x in w) >= 2
    # modern spelling warns nothing
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        p3 = mesh_policy(mesh)
        p4 = vec.on(MeshExecutor(mesh, "data"))
    assert p3.kind == p4.kind == "mesh"
    assert not [x for x in w2 if issubclass(x.category, DeprecationWarning)]


def test_unknown_flavor_rejected():
    with pytest.raises(ValueError):
        ExecutionPolicy("warp")


# ---------------------------------------------------------- consumer routing
def test_async_and_dataflow_accept_executor(rt):
    io_ex = rt.get_executor("io", fallback="default")
    before = _executed("io")
    assert core.async_(lambda a: a + 1, 41, executor=io_ex).get() == 42
    f = core.dataflow(lambda a, b: a * b,
                      core.async_(lambda: 6, executor=io_ex), 7,
                      executor=io_ex)
    assert f.get() == 42
    assert _executed("io") >= before + 2


def test_prefetcher_builds_on_io_pool(rt):
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, Prefetcher

    cfg = get_config("qwen25_3b", smoke=True)
    before = _executed("io")
    pf = Prefetcher(cfg, DataConfig(batch_size=2, seq_len=16, prefetch=1))
    batch = pf.get(0).get(timeout=60)
    assert batch["tokens"].shape == (2, 17)
    rt.drain(timeout=30)
    assert _executed("io") > before, "prefetch ran off the io pool"


def test_checkpoint_write_runs_on_io_pool(rt, tmp_path):
    from repro.checkpoint import ckpt

    before = _executed("io")
    state = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    out = ckpt.save_async(tmp_path, 3, state).get(timeout=60)
    assert (out / "manifest.json").exists()
    assert _executed("io") > before, "checkpoint write ran off the io pool"


def test_engine_prefill_runs_on_prefill_pool(rt):
    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config("starcoder2_3b", smoke=True)
    model = build_model(cfg, get_plan("serve"))
    params = model.init(jax.random.PRNGKey(0))
    grt = core.get_runtime()  # whatever runtime is live right now
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, cache_len=64, max_new_tokens=3,
                             name="engine#pools"))
    assert "prefill" in grt.pool_names()  # engine partitioned its pool
    before_pf = _executed("prefill")
    before_def = _executed("default")
    outs = [f.get(timeout=300) for f in
            [eng.submit([i + 1, i + 2, i + 3]) for i in range(4)]]
    assert all(len(o) == 4 for o in outs)
    assert _executed("prefill") >= before_pf + 4, "prefill off its pool"
    assert _executed("default") > before_def  # decode chain on compute pool
