import pytest

# Backfill optional test deps before any test module imports them: the shim
# registers itself as `hypothesis` ONLY when the real library is missing.
from repro import _hypothesis_shim

_hypothesis_shim.install_if_missing()


@pytest.fixture(scope="session")
def rt():
    """Session-wide AMT runtime (hpx::init equivalent)."""
    import repro.core as core

    runtime = core.init(num_workers=4, policy="local")
    yield runtime
    core.finalize()


@pytest.fixture()
def rng():
    import jax

    return jax.random.PRNGKey(0)
