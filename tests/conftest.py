import pytest


@pytest.fixture(scope="session")
def rt():
    """Session-wide AMT runtime (hpx::init equivalent)."""
    import repro.core as core

    runtime = core.init(num_workers=4, policy="local")
    yield runtime
    core.finalize()


@pytest.fixture()
def rng():
    import jax

    return jax.random.PRNGKey(0)
