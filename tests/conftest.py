import pytest

# Backfill optional test deps before any test module imports them: the shim
# registers itself as `hypothesis` ONLY when the real library is missing.
from repro import _hypothesis_shim

_hypothesis_shim.install_if_missing()


@pytest.fixture(scope="session")
def rt():
    """Session-wide AMT runtime (hpx::init equivalent)."""
    import repro.core as core

    runtime = core.init(num_workers=4, policy="local")
    yield runtime
    core.finalize()


@pytest.fixture()
def rng():
    import jax

    return jax.random.PRNGKey(0)


@pytest.fixture()
def net_factory(rt):
    """Leak-proof multi-locality bootstrap for tests: every runtime made
    through the factory is shut down (workers reaped) even when the test
    body raises — a failing test cannot strand processes and poison the
    rest of the suite."""
    import contextlib

    from repro import net as rnet

    with contextlib.ExitStack() as stack:
        yield lambda n, **kw: stack.enter_context(rnet.running(n, **kw))
