"""AGAS + parcels (HPX P3/P4)."""
import pytest

import repro.core as core
from repro.core import agas, parcel
from repro.core.agas import AGAS


def test_register_resolve_roundtrip(rt):
    a = AGAS(locality=7)
    gid = a.register({"v": 1}, name="/t/obj")
    assert a.resolve(gid) == {"v": 1}
    assert a.resolve("/t/obj") == {"v": 1}
    assert a.gid_of("/t/obj") == gid
    assert a.contains(gid) and a.contains("/t/obj")


def test_duplicate_name_rejected(rt):
    a = AGAS()
    a.register(1, name="/dup")
    with pytest.raises(KeyError):
        a.register(2, name="/dup")
    a.register_name("/dup", 3, replace=True)
    assert a.resolve("/dup") == 3


def test_unregister_after_adopt_keeps_rebound_name(rt):
    """adopt() rebinds a name to the adopted record; unregistering the OLD
    record must not tear down the live binding (migration's name-follows-
    the-object contract)."""
    from repro.core.agas import GID

    a = AGAS(locality=0)
    gid_old = a.register("old", name="/moves")
    rec = a.adopt(GID(9, 42), "new", name="/moves", generation=3)
    assert a.resolve("/moves") == "new"
    a.unregister(gid_old)
    assert a.resolve("/moves") == "new"
    assert a.gid_of("/moves") == rec.gid
    # unregistering the adopted record does clear the binding
    a.unregister(rec.gid)
    assert not a.contains("/moves")


def test_duplicate_name_leaves_no_orphan_record(rt):
    """A rejected bind must not insert a record first: an orphan would be
    pinned forever and (with the net tier up) republished to the root as
    a name → dead-GID mapping."""
    a = AGAS()
    a.register(1, name="/dup2")
    before = len(a)
    with pytest.raises(KeyError):
        a.register(2, name="/dup2")
    assert len(a) == before
    # every live record's name still resolves back to that record
    for rec in a:
        if rec.name is not None:
            assert a.gid_of(rec.name) == rec.gid


def test_unregister(rt):
    a = AGAS()
    gid = a.register("x", name="/gone")
    a.unregister(gid)
    assert not a.contains(gid)
    assert not a.contains("/gone")
    with pytest.raises(KeyError):
        a.resolve(gid)


def test_rebind_bumps_generation(rt):
    a = AGAS()
    gid = a.register([1, 2], name="/m")
    g1 = a.rebind(gid, [3, 4])
    g2 = a.rebind(gid, [5, 6])
    assert (g1, g2) == (1, 2)
    assert a.resolve("/m") == [5, 6]  # same name, migrated object


def test_names_prefix_listing(rt):
    a = AGAS()
    a.register(1, name="/app/x")
    a.register(2, name="/app/y")
    a.register(3, name="/other/z")
    assert a.names("/app/") == ["/app/x", "/app/y"]


def test_parcel_apply_executes_at_object(rt):
    gid = agas.default().register_name("/parcel/target", {"count": 10}, replace=True)
    fut = parcel.apply(lambda obj, d: obj["count"] + d, "/parcel/target", 5)
    assert fut.get() == 15


def test_parcel_action_decorator(rt):
    @parcel.action
    def scale(obj, s):
        return obj * s

    agas.default().register_name("/parcel/num", 6, replace=True)
    assert parcel.apply(scale, "/parcel/num", 7).get() == 42


def test_parcel_counters_increment(rt):
    from repro.core import counters

    before = counters.get_value("/parcel{port#0}/count/sent")
    agas.default().register_name("/parcel/c", 0, replace=True)
    parcel.apply(lambda o: o, "/parcel/c").get()
    assert counters.get_value("/parcel{port#0}/count/sent") == before + 1
