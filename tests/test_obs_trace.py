"""Trace recorder: ring-buffer correctness, context propagation, Chrome
conversion, and the disabled-cost contract (repro.obs.trace / export)."""

import threading

import pytest

from repro.obs import export, trace


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts disabled with empty buffers and leaves no state."""
    trace.disable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


# ----------------------------------------------------------------- disabled
def test_disabled_records_nothing():
    with trace.span("x", "t"):
        trace.instant("i", "t")
        trace.async_begin("r", 1)
        trace.async_end("r", 1)
    trace.complete("c", "t", 0.0)
    assert trace.events() == []


def test_disabled_span_is_shared_noop():
    assert trace.span("a", "t") is trace.span("b", "t")


def test_span_open_across_disable_drops_cleanly():
    trace.enable()
    s = trace.span("x", "t")
    with s:
        trace.disable()
    assert trace.events() == []  # no half-recorded span


# ------------------------------------------------------------------- spans
def test_span_records_complete_event_with_args():
    trace.enable()
    with trace.span("work", "sched", pool="default"):
        pass
    evs = trace.events()
    assert len(evs) == 1
    ph, name, cat, ts, dur, eid, args = evs[0]
    assert (ph, name, cat) == ("X", "work", "sched")
    assert dur >= 0.0 and args == {"pool": "default"}


def test_nested_span_records_parent_context():
    trace.enable()
    with trace.span("outer", "t") as outer:
        assert trace.current_context() == outer.sid
        with trace.span("inner", "t"):
            pass
    inner = [e for e in trace.events() if e[1] == "inner"][0]
    assert inner[6]["parent"] == f"{outer.sid[0]}:{outer.sid[1]}"
    assert trace.current_context() is None


def test_with_context_installs_foreign_parent():
    trace.enable()
    with trace.with_context((7, 42)):
        with trace.span("child", "net"):
            pass
    child = [e for e in trace.events() if e[1] == "child"][0]
    assert child[6]["parent"] == "7:42"


def test_flow_markers_surround_span():
    trace.enable()
    fid = trace.new_id()
    with trace.span("send", "net", flow_out=fid):
        pass
    with trace.span("recv", "net", flow_in=fid):
        pass
    phases = {e[0] for e in trace.events()}
    assert phases == {"X", "s", "f"}
    s = [e for e in trace.events() if e[0] == "s"][0]
    f = [e for e in trace.events() if e[0] == "f"][0]
    assert s[5] == f[5] == tuple(fid)


# ------------------------------------------------------------- ring buffer
def test_ring_wraparound_keeps_newest_and_counts_drops():
    buf = trace.TraceBuffer(capacity=8, tid=1, thread_name="t", epoch=0)
    for i in range(20):
        buf.append(("i", f"e{i}", "t", float(i), 0.0, None, None))
    evs, dropped = buf.snapshot()
    assert dropped == 12
    assert [e[1] for e in evs] == [f"e{i}" for i in range(12, 20)]


def test_ring_concurrent_writers_wraparound():
    """Each thread owns its own ring (the no-lock invariant); under heavy
    concurrent appends with wraparound every snapshot stays internally
    consistent: newest-suffix per thread, exact drop accounting."""
    trace.enable(capacity=64)
    n_threads, n_events = 8, 500
    barrier = threading.Barrier(n_threads)

    def writer(k: int) -> None:
        barrier.wait()
        for i in range(n_events):
            trace.instant(f"w{k}", "t", i=i)

    threads = [threading.Thread(target=writer, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    bufs = [b for b in trace.export_buffers()
            if b["events"] and b["events"][0][1].startswith("w")]
    assert len(bufs) == n_threads
    for b in bufs:
        names = {e[1] for e in b["events"]}
        assert len(names) == 1  # single-writer: no cross-thread bleed
        assert len(b["events"]) == 64
        assert b["dropped"] == n_events - 64
        seq = [e[6]["i"] for e in b["events"]]
        assert seq == list(range(n_events - 64, n_events))  # newest suffix


def test_clear_drops_events_and_reregisters():
    trace.enable()
    trace.instant("before", "t")
    trace.clear()
    assert trace.events() == []
    trace.instant("after", "t")
    assert [e[1] for e in trace.events()] == ["after"]


# ----------------------------------------------------------- chrome export
def test_chrome_conversion_shapes():
    trace.enable()
    fid = trace.new_id()
    with trace.span("send", "net", flow_out=fid, dst=1):
        pass
    trace.instant("mark", "t")
    trace.async_begin("request", 5, "serve")
    trace.async_end("request", 5, "serve")
    tr = export.merged_trace()
    by_ph = {}
    for e in tr["traceEvents"]:
        by_ph.setdefault(e["ph"], []).append(e)
    assert {"M", "X", "s", "i", "b", "e"} <= set(by_ph)
    x = by_ph["X"][0]
    assert x["ts"] >= 0 and x["dur"] >= 0  # µs, clock-corrected
    assert by_ph["s"][0]["id"] == f"{fid[0]}:{fid[1]}"
    assert by_ph["b"][0]["id"] == by_ph["e"][0]["id"]
    procs = [e for e in by_ph["M"] if e["name"] == "process_name"]
    assert procs and procs[0]["args"]["name"].startswith("locality#")


def test_flow_links_audit():
    trace.enable()
    fid = trace.new_id()
    with trace.span("send", "net", flow_out=fid):
        pass
    with trace.span("recv", "net", flow_in=fid):
        pass
    links = export.flow_links(export.merged_trace())
    key = f"{fid[0]}:{fid[1]}"
    assert links[key]["src"] is not None and links[key]["dst"] is not None
