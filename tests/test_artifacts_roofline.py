"""Dry-run artifact coherence + roofline arithmetic (reads results/dryrun)."""
import json
from pathlib import Path

import pytest

from repro.analysis.roofline import analyze, model_flops
from repro.configs import all_cells

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

pytestmark = pytest.mark.skipif(
    not RESULTS.exists() or not list(RESULTS.glob("*__futurized.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun --all)")


def _recs(mesh):
    return {(r["arch"], r["shape"]): r for r in (
        json.loads(p.read_text()) for p in RESULTS.glob(f"*__{mesh}__futurized.json"))}


@pytest.mark.parametrize("mesh,chips", [("pod", 256), ("multipod", 512)])
def test_every_live_cell_compiled(mesh, chips):
    recs = _recs(mesh)
    missing = [c for c in all_cells() if c not in recs]
    assert not missing, f"cells missing from {mesh} dry-run: {missing}"
    for (arch, shape), r in recs.items():
        assert r["n_devices"] == chips
        assert r["compile_s"] > 0
        assert r["hlo_flops_total"] > 0, (arch, shape)


def test_multipod_cells_cross_dci():
    """The pod axis must actually shard: train cells reduce grads across
    pods ⇒ nonzero DCI wire bytes."""
    recs = _recs("multipod")
    for (arch, shape), r in recs.items():
        if r["kind"] == "train":
            assert r["collectives"]["wire_bytes_dci"] > 0, (arch, shape)


def test_roofline_terms_positive_and_bottleneck_valid():
    for r in _recs("pod").values():
        a = analyze(r)
        assert a.compute_s > 0 and a.memory_s > 0
        assert a.bottleneck in ("compute", "memory", "collective")
        assert 0 < a.roofline_fraction < 1
        assert a.step_s == max(a.compute_s, a.memory_s, a.collective_s)


def test_model_flops_scales_with_kind():
    recs = _recs("pod")
    qt = recs[("qwen25_3b", "train_4k")]
    qp = recs[("qwen25_3b", "prefill_32k")]
    # train = 6·N·D, prefill = 2·N·D with equal token counts here
    assert abs(model_flops(qt) / model_flops(qp) - 3.0) < 1e-6


def test_decode_cells_lower_serve_step_not_train():
    recs = _recs("pod")
    for (arch, shape), r in recs.items():
        if shape in ("decode_32k", "long_500k"):
            assert r["kind"] == "decode"
            # decode flops orders of magnitude below train flops
            tr = recs.get((arch, "train_4k"))
            if tr:
                assert r["hlo_flops_total"] < tr["hlo_flops_total"] / 50
