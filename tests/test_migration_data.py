"""Migration (elastic resharding) + data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.configs import get_config
from repro.core import agas, migration
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch


def _sh():
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


def test_migrate_tree_preserves_values():
    tree = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones((4,))}
    moved = migration.migrate_tree(tree, _sh())
    np.testing.assert_array_equal(np.asarray(moved["w"]), np.asarray(tree["w"]))
    assert moved["w"].sharding == _sh()


def test_agas_migration_generation_and_identity(rt):
    gid = agas.default().register({"x": jnp.ones((8,))})
    gen = migration.migrate(gid, _sh())
    assert gen == 1
    rec = agas.default().record(gid)
    assert rec.placement == _sh()
    np.testing.assert_array_equal(np.asarray(rec.obj["x"]), np.ones((8,)))
    gen2 = migration.migrate(gid, _sh())
    assert gen2 == 2  # GID stable across migrations


def test_migrate_generation_never_stale_under_concurrent_resolve(rt):
    """Property: after migrate() returns generation g, every subsequent
    resolve observes generation >= g and the matching placement — readers
    racing the migration never see a *rolled-back* record."""
    import threading

    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=2, max_value=5),
           st.integers(min_value=3, max_value=12))
    def prop(n_readers, n_migrations):
        a = agas.AGAS(locality=0)
        gid = a.register({"x": jnp.arange(4.0)}, placement="gen0")
        stop = threading.Event()
        violations = []

        def reader():
            # generation and placement-index must each be monotonic from
            # any reader's viewpoint: a decrease = a rolled-back (stale)
            # record became visible after a later one
            last_gen, last_idx = -1, -1
            while not stop.is_set():
                rec = a.record(gid)
                gen = rec.generation
                idx = int(str(rec.placement)[3:])
                if gen < last_gen or idx < last_idx:
                    violations.append((last_gen, gen, last_idx, idx))
                last_gen, last_idx = max(last_gen, gen), max(last_idx, idx)

        threads = [threading.Thread(target=reader, daemon=True)
                   for _ in range(n_readers)]
        for t in threads:
            t.start()
        try:
            for k in range(1, n_migrations + 1):
                moved = migration.migrate_tree(a.resolve(gid), _sh())
                gen = a.rebind(gid, moved, placement=f"gen{k}")
                assert gen == k
                # the bound just returned must be visible immediately
                rec = a.record(gid)
                assert rec.generation >= gen
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=10)
        assert not violations, violations[:3]

    prop()


def test_synth_batch_deterministic_per_step():
    cfg = get_config("qwen25_3b", smoke=True)
    d = DataConfig(batch_size=2, seq_len=16, seed=3)
    a = synth_batch(cfg, d, step=5)
    b = synth_batch(cfg, d, step=5)
    c = synth_batch(cfg, d, step=6)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_synth_batch_tokens_in_vocab():
    cfg = get_config("granite_moe_3b_a800m", smoke=True)
    b = synth_batch(cfg, DataConfig(batch_size=4, seq_len=32), step=0)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < cfg.vocab_size
    assert t.shape == (4, 33)


def test_prefetcher_returns_futures_and_counts(rt):
    from repro.core import counters

    cfg = get_config("qwen25_3b", smoke=True)
    pf = Prefetcher(cfg, DataConfig(batch_size=2, seq_len=16))
    before = counters.get_value("/data{pipeline#0}/batches/built")
    b0 = pf.get(0).get(timeout=60)
    b1 = pf.get(1).get(timeout=60)
    assert b0["tokens"].shape == (2, 17)
    # prefetch window built ahead
    import time
    time.sleep(0.3)
    assert counters.get_value("/data{pipeline#0}/batches/built") >= before + 2
