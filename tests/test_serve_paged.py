"""Paged continuous-batching serving stack: per-slot divergence, page-pool
reuse, streaming, sampling, routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.future import Channel, ChannelClosed
from repro.dist.plan import get_plan
from repro.models.model import build_model
from repro.serve.engine import Engine, SamplingParams, ServeConfig
from repro.serve.router import Router


@pytest.fixture(scope="module")
def served():
    cfg = get_config("starcoder2_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _manual_greedy(model, params, prompt, n):
    pin = {"tokens": jnp.asarray(prompt, jnp.int32)[None, :]}
    logits, cache = jax.jit(model.prefill, static_argnames=("cache_len",))(
        params, pin, cache_len=96)
    out = [int(jnp.argmax(logits, -1)[0])]
    dec = jax.jit(model.decode)
    for _ in range(n):
        logits, cache = dec(params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def _truncate_at_eos(toks, eos):
    out = []
    for t in toks:
        out.append(t)
        if t == eos:
            break
    return out


def test_per_slot_length_divergence(rt, served):
    """Requests with different max_new share the batch; every slot must
    match its own reference decode (per-row lengths in the kernel)."""
    cfg, model, params = served
    prompts = [[5, 6, 7, 8], [100, 3, 50, 2, 9, 11], [42, 7]]
    new = [2, 7, 4]
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=8))
    futs = [eng.submit(p, max_new=n) for p, n in zip(prompts, new)]
    outs = [f.get(timeout=300) for f in futs]
    for p, n, o in zip(prompts, new, outs):
        assert o == _manual_greedy(model, params, p, n), (p, n)


def test_per_slot_eos_divergence(rt, served):
    """EOS ends one slot early while its batch-mates continue exactly."""
    cfg, model, params = served
    pa, pb = [5, 6, 7, 8], [100, 3, 50, 2, 9, 11]
    n = 6
    ra = _manual_greedy(model, params, pa, n)
    rb = _manual_greedy(model, params, pb, n)
    # pick an eos whose *first* occurrence in ra is mid-sequence
    k = next(i for i in range(1, n) if ra[i] not in ra[:i])
    eos = ra[k]
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=n, eos_id=eos))
    fa = eng.submit(pa)
    fb = eng.submit(pb)
    assert fa.get(timeout=300) == _truncate_at_eos(ra, eos)
    assert fb.get(timeout=300) == _truncate_at_eos(rb, eos)
    assert len(fa.get()) == k + 1 < n + 1  # ended early, batch-mate exact


def test_paged_free_list_reuse_under_churn(rt, served):
    """Admission churn cycles pages through the free list: cumulative
    allocations exceed pool capacity (reuse) and everything returns."""
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=64,
                                            max_new_tokens=3, page_size=16,
                                            name="churn#0"))
    kv = eng.backend.kv
    futs = [eng.submit(list(range(1, 2 + i % 17))) for i in range(9)]
    outs = [f.get(timeout=300) for f in futs]
    assert all(len(o) == 4 for o in outs)
    assert kv.pages_in_use() == 0
    assert kv.free_pages() == kv.num_pages - 1
    assert eng.c_sub.get_value() - eng.c_done.get_value() == 0
    from repro.core import counters
    assert counters.get_value("/serve{churn#0}/pages/allocated") > kv.num_pages - 1
    assert (counters.get_value("/serve{churn#0}/pages/allocated")
            == counters.get_value("/serve{churn#0}/pages/freed"))


def test_stream_channel_order_and_close(rt, served):
    """Streamed tokens arrive in generation order, the first before the
    request completes, and the channel closes on finish."""
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=16))
    ch, fut = eng.submit_stream([5, 6, 7, 8])
    # 16 decode steps (seconds) remain when the first token arrives — wide
    # margin against scheduler jitter on a loaded CI machine
    first = ch.get(timeout=300)
    assert not fut.is_ready(), "first token must stream before completion"
    rest = list(ch)
    out = fut.get(timeout=300)
    assert [first] + rest == out
    with pytest.raises(ChannelClosed):
        ch.get(timeout=1)


def test_greedy_sampling_equivalence_at_t0(rt, served):
    """temperature=0 reduces to exact argmax regardless of top-k/top-p."""
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=5))
    p = [9, 8, 7, 6]
    o_plain = eng.submit(p).get(timeout=300)
    o_t0 = eng.submit(p, sampling=SamplingParams(temperature=0.0, top_k=7,
                                                 top_p=0.5)).get(timeout=300)
    assert o_plain == o_t0 == _manual_greedy(model, params, p, 5)


def test_sampling_respects_top_k(rt, served):
    """Sampled tokens with top_k=1 are exactly the greedy sequence (the
    nucleus of one); higher temperature still yields valid token ids."""
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=4))
    p = [5, 6, 7, 8]
    o_k1 = eng.submit(p, sampling=SamplingParams(temperature=0.7, top_k=1)
                      ).get(timeout=300)
    assert o_k1 == _manual_greedy(model, params, p, 4)
    o_hot = eng.submit(p, sampling=SamplingParams(temperature=1.2, top_k=20)
                       ).get(timeout=300)
    assert all(0 <= t < cfg.vocab_size for t in o_hot)


def test_router_least_loaded_dispatch(rt, served):
    """The router reads per-engine in-flight counters and avoids the busy
    replica."""
    cfg, model, params = served
    scfg = ServeConfig(max_batch=2, cache_len=64, max_new_tokens=2)
    router = Router.replicate(model, params, scfg, 2)
    e0, e1 = router.engines
    assert router.pick() == 0  # ties → first
    e0.c_sub.increment(3)  # fake 3 in-flight requests on replica 0
    assert e0.load() == 3 and e1.load() == 0
    assert router.pick() == 1
    out = router.submit([4, 5, 6]).get(timeout=300)
    assert len(out) == 3
    from repro.core import counters
    assert counters.get_value("/serve{router}/dispatch/engine#1") >= 1
    assert counters.get_value("/serve{engine#1}/requests/completed") >= 1
    e0.c_sub.increment(-3)  # restore


def test_seed_parity_mode_matches_greedy(rt, served):
    """The A/B baseline (dense cache + inline-prefill barrier) still
    produces exact greedy tokens — the bench compares against it."""
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=96,
                                            max_new_tokens=4, paged=False,
                                            pipeline_admission=False))
    assert not eng.paged
    p = [11, 12, 13]
    assert eng.submit(p).get(timeout=300) == _manual_greedy(model, params, p, 4)


def test_decode_step_compiles_once(rt, served):
    """Admission churn (different prompt lengths, sampling params, EOS
    timings) never changes decode-step shapes: one compile, total."""
    cfg, model, params = served
    eng = Engine(model, params, ServeConfig(max_batch=2, cache_len=64,
                                            max_new_tokens=3))
    futs = [eng.submit(list(range(1, 2 + i)),
                       sampling=SamplingParams(temperature=0.5 * (i % 2),
                                               top_k=i))
            for i in range(5)]
    for f in futs:
        f.get(timeout=300)
    assert eng.decode_compile_count() == 1
